"""Representation-quality tables: Fig 8 probe (trained), Table 3 retrieval,
Table 5 hybrid-loss ablation under frame drops, §3.3 metric validation,
Fig 9 uncertainty calibration."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from benchmarks.edge_train import (linear_probe, retrieval_metrics,
                                   train_representation)

STEPS = 220


def bench_probe_and_retrieval():
    """Fig 8 (trained proxy) + Table 3: probe acc and retrieval metrics for
    the three trainable regimes at CPU scale."""
    paper_probe = {"edge_only": 58.6, "streamsplit": 71.8, "server": 73.6}
    paper_ret = {"edge_only": (0.287, 26.4), "streamsplit": (0.412, 38.7),
                 "server": (0.431, 40.2)}
    res = {}
    for mode in ("edge_only", "streamsplit", "server"):
        r = train_representation(mode, steps=STEPS, eval_n=240)
        res[mode] = r
        mAP, r1 = retrieval_metrics(r.eval_z, r.eval_y)
        row(f"fig8_probe_acc[{mode}]", 100 * r.probe_acc,
            f"paper:{paper_probe[mode]}")
        row(f"fig8_collapse[{mode}]", r.collapse,
            "mean |cos| (1.0 = dimensional collapse)")
        row(f"table3_mAP10[{mode}]", mAP, f"paper:{paper_ret[mode][0]}")
        row(f"table3_R1_pct[{mode}]", 100 * r1,
            f"paper:{paper_ret[mode][1]}")
    ok = (res["edge_only"].probe_acc <= res["streamsplit"].probe_acc
          <= res["server"].probe_acc + 0.05)
    row("fig8_ordering_reproduced", float(ok),
        "edge_only <= streamsplit <= server")


def bench_loss_ablation():
    """Table 5: loss variants x frame-drop rates."""
    paper = {
        ("mse", 0.0): 69.2, ("mse", 0.4): 52.8,
        ("kl", 0.0): 70.1, ("kl", 0.4): 55.1,
        ("task_sw", 0.0): 70.8, ("task_sw", 0.4): 61.3,
        ("task_lap", 0.0): 70.4, ("task_lap", 0.4): 60.7,
        ("hybrid", 0.0): 71.8, ("hybrid", 0.4): 65.2,
    }
    accs = {}
    for variant in ("mse", "kl", "task_sw", "task_lap", "hybrid"):
        for drop in (0.0, 0.4):
            r = train_representation("streamsplit", steps=STEPS, eval_n=200,
                                     drop_rate=drop, variant=variant)
            accs[(variant, drop)] = r.probe_acc
            row(f"table5_probe_acc[{variant},drop={drop}]",
                100 * r.probe_acc, f"paper:{paper[(variant, drop)]}")
    # headline: hybrid degrades least under 40% drops
    degr = {v: accs[(v, 0.0)] - accs[(v, 0.4)]
            for v in ("mse", "kl", "hybrid")}
    row("table5_hybrid_most_robust",
        float(degr["hybrid"] <= min(degr["mse"], degr["kl"]) + 0.03),
        f"degradations:{ {k: round(100*v,1) for k,v in degr.items()} }")


def bench_metric_validation():
    """§3.3: SWD vs accuracy correlation across collapse levels (cones) and
    L_Lap vs jitter."""
    from repro.core.swd import mmd_rbf, swd_loss
    from repro.core.laplacian import dirichlet_energy, spectral_gap, \
        temporal_adjacency
    key = jax.random.PRNGKey(0)
    d, n = 32, 512

    def cone(k, ang):
        z = jax.random.normal(k, (n, d))
        z = z / jnp.linalg.norm(z, -1, keepdims=True)
        t = np.cos(np.radians(ang))
        axis = jnp.zeros((d,)).at[0].set(1.0)
        z = t * axis[None] + (1 - t) * z
        return z / jnp.linalg.norm(z, -1, keepdims=True)

    angles = list(range(10, 100, 10))
    # quality proxy: embedding diversity = 1 - mean pairwise |cos| (the
    # discriminative capacity the paper's downstream accuracy tracks)
    sw, acc_proxy = [], []
    for ang in angles:
        z = np.asarray(cone(jax.random.PRNGKey(ang), ang))
        sw.append(float(swd_loss(key, jnp.asarray(z), n_dirs=64)))
        sim = np.abs(z @ z.T)
        acc_proxy.append(1.0 - float((sim.sum() - n) / (n * (n - 1))))
    r_sw = float(np.corrcoef(sw, acc_proxy)[0, 1])
    row("s33_swd_quality_corr_r", r_sw, "paper:-0.96 (strong negative)")

    # jitter: L_Lap rises, spectral gap falls
    t = np.linspace(0, 6 * np.pi, 80)
    z = np.stack([np.cos(t), np.sin(t), 0.5 * np.cos(2 * t)], -1)
    rng = np.random.default_rng(0)
    laps, ps = [], list(np.arange(0, 0.9, 0.1))
    for p in ps:
        zj = z.copy()
        idx = rng.random(80) < p
        perm = rng.permutation(np.where(idx)[0])
        zj[np.where(idx)[0]] = zj[perm]
        laps.append(float(dirichlet_energy(jnp.asarray(zj), k=5)))
    r_lap = float(np.corrcoef(ps, laps)[0, 1])
    row("s33_lap_jitter_corr_r", r_lap, "paper:0.93 (strong positive)")
    gap_clean = spectral_gap(temporal_adjacency(80, 5))
    mask = (rng.random(80) > 0.4).astype(float)
    gap_drop = spectral_gap(temporal_adjacency(80, 5, mask=mask))
    row("s33_spectral_gap_clean_vs_40drop", gap_clean,
        f"dropped:{gap_drop:.3f} (paper: 0.42 -> 0.08)")


def bench_uncertainty_calibration():
    """Fig 9: GMM entropy vs difficulty — measured with a TRAINED encoder
    (an untrained one's entropies are uninformative: r ≈ -0.1)."""
    from repro.core import gmm as G
    from benchmarks.edge_train import ENC, _encode
    from repro.data.audio_stream import AudioStream, StreamCfg
    from repro.data.audio_stream import augment_pair
    res = train_representation("streamsplit", steps=150, eval_n=80)
    params = res.params
    gmm = G.init_gmm(jax.random.PRNGKey(1), 16, ENC.d_embed)
    stream = AudioStream(StreamCfg(seed=3))
    rng = np.random.default_rng(3)
    us, hard = [], []
    for i in range(60):
        mels, ys, groups = stream.batch(8)
        m1, m2 = zip(*[augment_pair(rng, m[: ENC.frames]) for m in mels])
        z1 = _encode(params, jnp.asarray(np.stack(m1)))
        z2 = _encode(params, jnp.asarray(np.stack(m2)))
        u = np.asarray(G.normalized_entropy(gmm, z1))
        gmm = G.em_update(gmm, z1, decay=0.1)
        if i >= 10:  # after the GMM warms up
            us += list(u)
            # per-frame hardness = view disagreement (the loss the server
            # would reduce): frames the encoder can't pin down move most
            # under augmentation — the paper's "server utility" proxy
            hard += list(1.0 - np.sum(np.asarray(z1) * np.asarray(z2), -1))
    r = float(np.corrcoef(us, hard)[0, 1])
    row("fig9_uncertainty_vs_difficulty_r", r,
        "paper:0.84 — NOT reproduced at CPU scale (r~0 with C=16, d=32; "
        "see EXPERIMENTS.md)")


def run_all():
    bench_probe_and_retrieval()
    bench_loss_ablation()
    bench_metric_validation()
    bench_uncertainty_calibration()
