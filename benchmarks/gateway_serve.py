"""Gateway serving benchmark: k-bucketed batched dispatch vs the
per-frame ``SplitEngine.run`` loop, and the overlapped single-sync tick
vs the PR-3 per-bucket-sync dispatch.

Two lanes:

1. **Entropy lane** (the PR-2 contract): N concurrent sessions, the
   entropy policy routes them into two k-buckets (easy -> fully local
   k=L, hard -> shallow split k=2), so every tick is a handful of padded
   dispatches instead of one 3-executable chain per frame.  Measured
   against the per-frame ``run`` loop (the seed's serving model).

2. **Mixed-k lane** (the PR-4 contract): a deep thin encoder (L=8) and a
   policy that spreads frames over every split index — 9 k-buckets per
   tick.  The same workload is served through ``overlap=False`` (the
   PR-3 data plane: host staging + one blocking device round-trip per
   bucket) and ``overlap=True`` (ONE staged H2D, async bucket chains,
   ONE sync + ONE D2H per tick).  Reports frames/s, the measured
   syncs/tick and staged H2D bytes, and mean/p50/p95 tick latency.

Every path warms up its per-k executables (and every pow2 batch-shape
bucket) BEFORE the timed region — first-tick XLA compile never pollutes
a frames/s number — and asserts bit-parity against the per-frame
``SplitEngine.run`` reference before reporting any throughput.

Regime note: the speedup of lane 2 is bounded by how much work can
actually overlap.  On a CPU-only jax (this repo's CI) the "device" is a
thread pool sharing cores with the dispatching host thread, so the
single-sync plane wins exactly as much host-side dispatch time as the
spare cores can absorb (~1.3-1.7x on a 2-core runner, ~1.0x when
throttled to one).  On an accelerator backend every per-bucket
round-trip the PR-3 path pays is a real H2D/D2H + launch-latency stall,
which is the ≥2x regime the paper's latency claims live in (docs/PERF.md
walks through the pipeline stages and where the one sync point sits).

    PYTHONPATH=src python -m benchmarks.gateway_serve [--quick|--smoke]
                                                      [--shards S]

``--shards S`` additionally serves the entropy lane through the SHARDED
DISPATCH plane (docs/SHARDING.md): a device-resident
``ShardedFleetBackend`` over S forced host devices with
``shard_dispatch`` auto-enabled, so the per-tick edge→wire→server chains
run per device, co-located with each session's fleet shard.  The lane
always runs — a session count that does not divide over S pads the
fleet capacity up, never skips — and asserts the same bit-parity plus
the one-sync/one-D2H contract at every shard count before reporting.
Sharded runs MERGE into an existing ``BENCH_gateway.json`` under the
``shards[S]`` dimension (run the base bench first, then one process per
shard count: ``force_host_devices`` must set ``XLA_FLAGS`` before jax
initializes).

Regime note for ``--shards`` numbers: forced host devices SLICE one
CPU's cores into S fake devices — they add no compute, so frames/s
scaling with S only manifests on real multi-chip meshes (or hosts with
cores to spare); what CI pins is the contracts (parity, one sync,
shard-local ingest), with throughput recorded per backend.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import pcts as _pcts
from benchmarks.common import row

ENC_KW = dict(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2), n_mels=16,
              frames=16, d_embed=32, groups=4)
# the mixed-k lane's encoder: deep (9 split points -> 9 buckets/tick)
# and thin — the paper's small streaming edge-CNN regime, where
# per-bucket dispatch overhead, not FLOPs, dominates the serving loop
DEEP_KW = dict(widths=(8,) * 8, strides=(1,) * 8, n_mels=8, frames=8,
               d_embed=16, groups=2)
SIZES = (8, 32, 128)
MIXED_SIZES = (32, 64)
OFFLOAD_K = 2
THRESHOLD = 0.5


class MixedKPolicy:
    """Deterministic mixed-k policy: uncertainty quantile -> split index,
    spreading one tick over every k in [0, L] (L+1 buckets)."""

    def __init__(self, L):
        self.L = L

    def decide(self, obs_batch):
        return np.clip((obs_batch[:, 0] * (self.L + 1)).astype(np.int64),
                       0, self.L)


def _setup(n, *, shards=0, enc_kw=ENC_KW, policy=None, overlap=True):
    from repro.api import (ShardedFleetBackend, StreamSplitGateway,
                           make_policy)
    from repro.core.splitter import SplitEngine
    from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
    cfg = AudioEncCfg(**enc_kw)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mels = rng.normal(size=(n, cfg.frames, cfg.n_mels)).astype(np.float32)
    # uncertainty spread straddling the threshold 50/50 — the cascade's
    # calibrated operating point (CascadeServer auto-calibrates its
    # threshold to a quantile of observed entropies for the same reason)
    us = rng.permutation(np.linspace(0.05, 0.95, n))
    if policy is None:
        policy = make_policy("entropy", cfg.n_blocks, threshold=THRESHOLD,
                             offload_k=OFFLOAD_K)
    obs = np.stack([us, np.zeros(n), np.zeros(n)], 1).astype(np.float32)
    ks = policy.decide(obs)
    if shards:
        from repro.launch.mesh import make_sessions_mesh
        # pad capacity up to a multiple of the shard count so the lane
        # runs at ANY n (the old gate skipped n % shards != 0 silently)
        cap = -(-n // shards) * shards
        backend = ShardedFleetBackend(capacity=cap, window=16,
                                      dim=cfg.d_embed,
                                      mesh=make_sessions_mesh(shards))
    else:
        backend = None
    gw = StreamSplitGateway(cfg, params, policy=policy, capacity=n,
                            window=16, qos_reserve=0, backend=backend,
                            overlap=overlap)
    sids = [gw.open_session().sid for _ in range(n)]
    return cfg, params, SplitEngine(cfg), gw, sids, mels, us, ks


def bench_gateway(n, *, iters, shards=0, baseline=True):
    """-> (per-frame f/s, gateway f/s, bit_identical, tick percentiles,
    stats).  Same frames, same k assignment, both materializing every
    embedding.  ``baseline=False`` skips the per-frame timing repetitions
    (the sharded lane reuses the numbers already measured) — the parity
    reference round still runs."""
    from repro.api import FrameRequest
    cfg, params, eng, gw, sids, mels, us, ks = _setup(n, shards=shards)

    def submit_all(t):
        for i, sid in enumerate(sids):
            gw.submit(sid, FrameRequest(t=t, mel=mels[i], u=float(us[i])))

    def per_frame_round():
        return [np.asarray(eng.run(params, mels[i:i + 1], int(ks[i]))[0])[0]
                for i in range(n)]

    # warmup: compile every per-k executable (and every pow2 bucket
    # shape) BOTH paths touch, before anything is timed
    submit_all(0)
    results = gw.tick()
    z_ref = per_frame_round()
    submit_all(1)
    gw.tick()

    # parity first: a fast wrong answer is not a result
    bit_identical = all((r.z == z_ref[i]).all() and r.k == ks[i]
                        for i, r in enumerate(results))

    # timeit-style best-of-repeats: the min time of each path suppresses
    # scheduler/contention noise (the batched path threads across cores,
    # so background load hits it disproportionately)
    pf_best, gw_best = float("inf"), float("inf")
    tick_ms: list[float] = []
    tick = 2
    for _ in range(5):
        if baseline:
            t0 = time.perf_counter()
            for _ in range(iters):
                per_frame_round()
            pf_best = min(pf_best, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _ in range(iters):
            submit_all(tick)
            t1 = time.perf_counter()
            gw.tick()
            tick_ms.append((time.perf_counter() - t1) * 1e3)
            tick += 1
        gw_best = min(gw_best, time.perf_counter() - t0)
    return n * iters / pf_best, n * iters / gw_best, bit_identical, \
        _pcts(tick_ms), gw.stats()


def bench_mixed(n, *, iters, repeats=6):
    """The overlapped single-sync plane vs the PR-3 per-bucket-sync path
    on an L+1-bucket mixed-k tick.  Both gateways serve identical frames
    with identical k assignments; embeddings are asserted bit-identical
    to each other AND to the per-frame ``SplitEngine.run`` reference
    before any number is reported.  Repeats are interleaved sync/async so
    machine drift hits both paths equally."""
    from repro.api import FrameRequest
    from repro.models.audio_encoder import AudioEncCfg
    L = AudioEncCfg(**DEEP_KW).n_blocks
    lanes = {}
    for mode, overlap in (("sync", False), ("async", True)):
        cfg, params, eng, gw, sids, mels, us, ks = _setup(
            n, enc_kw=DEEP_KW, policy=MixedKPolicy(L), overlap=overlap)
        lanes[mode] = dict(gw=gw, sids=sids, mels=mels, us=us, ks=ks,
                           eng=eng, params=params, times=[], best=float("inf"))
    n_buckets = len(set(int(k) for k in lanes["sync"]["ks"]))
    assert n_buckets >= 4, f"mixed-k lane needs >=4 buckets, got {n_buckets}"

    def submit_all(mode, t):
        ln = lanes[mode]
        for i, sid in enumerate(ln["sids"]):
            ln["gw"].submit(sid, FrameRequest(t=t, mel=ln["mels"][i],
                                              u=float(ln["us"][i])))

    # warmup + parity: both paths vs the per-frame reference, bitwise
    ln = lanes["sync"]
    z_ref = [np.asarray(ln["eng"].run(ln["params"], ln["mels"][i:i + 1],
                                      int(ln["ks"][i]))[0])[0]
             for i in range(n)]
    first = {}
    for mode in ("sync", "async"):
        submit_all(mode, 0)
        first[mode] = lanes[mode]["gw"].tick()
        submit_all(mode, 1)
        lanes[mode]["gw"].tick()
    bit_identical = all(
        (ra.z == rs.z).all() and (ra.z == z_ref[i]).all() and ra.k == rs.k
        for i, (ra, rs) in enumerate(zip(first["async"], first["sync"])))

    tick = 2
    for _ in range(repeats):
        for mode in ("sync", "async"):
            ln = lanes[mode]
            t = tick
            t0 = time.perf_counter()
            for _ in range(iters):
                submit_all(mode, t)
                t1 = time.perf_counter()
                ln["gw"].tick()
                ln["times"].append((time.perf_counter() - t1) * 1e3)
                t += 1
            ln["best"] = min(ln["best"],
                             (time.perf_counter() - t0) / iters)
        tick += iters
    sync_fps = n / lanes["sync"]["best"]
    async_fps = n / lanes["async"]["best"]
    st_a = lanes["async"]["gw"].stats()
    st_s = lanes["sync"]["gw"].stats()
    # the single-sync contract, measured off the instrumented counters
    assert st_a.device_syncs_per_tick == 1 and st_a.d2h_copies_per_tick == 1
    assert st_s.device_syncs_per_tick == n_buckets
    return {
        "n": n,
        "buckets_per_tick": n_buckets,
        "bit_identical": bool(bit_identical),
        "sync_fps": sync_fps,
        "async_fps": async_fps,
        "speedup": async_fps / sync_fps,
        "device_syncs_per_tick": {"sync": st_s.device_syncs_per_tick,
                                  "async": st_a.device_syncs_per_tick},
        "staged_h2d_bytes_per_tick": st_a.staged_h2d_bytes // st_a.ticks,
        "tick_ms": {"sync": _pcts(lanes["sync"]["times"]),
                    "async": _pcts(lanes["async"]["times"])},
    }


def run_all(*, quick=False, shards=0, smoke=False):
    sizes = [n for n in SIZES if not ((quick or smoke) and n > 32)]
    result = {}
    for n in sizes:
        iters = max(2 if smoke else 4, (32 if smoke else 128) // n)
        pf, gwf, exact, pcts, _ = bench_gateway(n, iters=iters)
        assert exact, f"gateway embeddings diverged from per-frame at N={n}"
        speedup = gwf / pf
        result[n] = {"per_frame_fps": pf, "gateway_fps": gwf,
                     "speedup": speedup, "bit_identical": exact,
                     "tick_ms": pcts}
        row(f"gateway.per_frame.N{n}", 1e6 / pf, "frames/s baseline")
        row(f"gateway.bucketed.N{n}", 1e6 / gwf,
            f"{speedup:.1f}x vs per-frame, bit-identical, tick p50 "
            f"{pcts['p50']:.2f}ms p95 {pcts['p95']:.2f}ms")
        if shards:
            _, shf, exact_s, spcts, st = bench_gateway(n, iters=iters,
                                                       shards=shards,
                                                       baseline=False)
            assert exact_s, \
                f"sharded-dispatch embeddings diverged at N={n}"
            assert st.ingest_h2d_bytes == 0, \
                "device-resident ingest must not move embedding payload"
            assert st.device_syncs_per_tick == 1 \
                and st.d2h_copies_per_tick == 1, \
                f"sharded dispatch broke the one-sync contract at N={n}: " \
                f"{st.device_syncs_per_tick} syncs, {st.d2h_copies_per_tick} d2h"
            assert st.dispatch_shards == shards, \
                f"dispatch plane ran on {st.dispatch_shards} shards, " \
                f"asked for {shards}"
            assert sum(st.dispatch_shard_frames) == st.frames, \
                "per-shard dispatch counts do not cover every frame"
            result[n]["sharded_fps"] = shf
            result[n]["sharded"] = {
                "shards": st.shards,
                "dispatch_shards": st.dispatch_shards,
                "dispatch_shard_frames": list(st.dispatch_shard_frames),
                "shard_frames": list(st.shard_frames),
                "padded_capacity": -(-n // shards) * shards,
                "device_syncs_per_tick": st.device_syncs_per_tick,
                "tick_ms": spcts,
                "ingest_h2d_bytes": st.ingest_h2d_bytes,
                "snapshot_h2d_bytes": st.snapshot_h2d_bytes}
            row(f"gateway.dispatch.sharded{st.dispatch_shards}.N{n}",
                1e6 / shf,
                f"{shf / pf:.1f}x vs per-frame, bit-identical, 1 sync/tick, "
                f"per-shard frames {list(st.dispatch_shard_frames)}, "
                f"tick p50 {spcts['p50']:.2f}ms p95 {spcts['p95']:.2f}ms")
    if shards:   # sharded runs merge into an existing base JSON
        print("BENCH " + json.dumps(
            {"bench": "gateway_serve", "shards": shards,
             **{str(k): v for k, v in result.items()}}))
        return result
    result["mixed_k"] = {}
    for n in MIXED_SIZES:
        m = bench_mixed(n, iters=max(2 if smoke else 8, 64 // n),
                        repeats=3 if smoke else 6)
        assert m["bit_identical"], \
            f"mixed-k overlapped embeddings diverged at N={n}"
        result["mixed_k"][n] = m
        row(f"gateway.mixed.sync.N{n}", 1e6 / m["sync_fps"],
            f"PR-3 baseline: {m['buckets_per_tick']} syncs/tick, tick p50 "
            f"{m['tick_ms']['sync']['p50']:.2f}ms "
            f"p95 {m['tick_ms']['sync']['p95']:.2f}ms")
        row(f"gateway.mixed.async.N{n}", 1e6 / m["async_fps"],
            f"{m['speedup']:.2f}x vs per-bucket-sync, 1 sync/tick, "
            f"bit-identical, tick p50 {m['tick_ms']['async']['p50']:.2f}ms "
            f"p95 {m['tick_ms']['async']['p95']:.2f}ms")
    print("BENCH " + json.dumps({"bench": "gateway_serve",
                                 "enc": ENC_KW["widths"],
                                 "threshold": THRESHOLD,
                                 "offload_k": OFFLOAD_K, **
                                 {str(k): v for k, v in result.items()}}))
    return result


def write_bench_json(result, path="BENCH_gateway.json", shards=0):
    """Machine-readable perf trajectory (tracked across PRs; uploaded as
    a CI artifact — see docs/PERF.md for how to read it).

    Schema 2 adds the ``shards`` dimension: a base run (``shards=0``)
    rewrites ``mixed_k``/``entropy`` while PRESERVING any ``shards``
    entries already on disk, and a ``--shards S`` run updates only
    ``shards[S]`` — so one base process plus one forced-device process
    per shard count compose a single trajectory file (each process must
    be fresh: the host device count is locked at first jax init)."""
    doc = {"bench": "gateway_serve", "schema": 2,
           "backend": jax.default_backend(),
           "mixed_k": {}, "entropy": {}, "shards": {}}
    try:
        with open(path) as f:
            old = json.load(f)
        if old.get("bench") == "gateway_serve":
            for key in ("mixed_k", "entropy", "shards"):
                doc[key] = old.get(key, {})
    except (OSError, ValueError):
        pass
    if shards:
        doc["shards"][str(shards)] = {
            str(n): {
                "frames_per_s": v["sharded_fps"],
                "frames_per_s_unsharded_same_host": v["gateway_fps"],
                "dispatch_shard_frames": v["sharded"][
                    "dispatch_shard_frames"],
                "padded_capacity": v["sharded"]["padded_capacity"],
                "device_syncs_per_tick": v["sharded"][
                    "device_syncs_per_tick"],
                "tick_ms": v["sharded"]["tick_ms"],
                "bit_identical": v["bit_identical"],
            } for n, v in result.items() if isinstance(n, int)}
    else:
        mixed = result.get("mixed_k", {})
        doc["mixed_k"] = {
            str(n): {
                "frames_per_s": {"sync": m["sync_fps"],
                                 "async": m["async_fps"]},
                "speedup_async_vs_sync": m["speedup"],
                "buckets_per_tick": m["buckets_per_tick"],
                "device_syncs_per_tick": m["device_syncs_per_tick"],
                "staged_h2d_bytes_per_tick": m["staged_h2d_bytes_per_tick"],
                "tick_ms": m["tick_ms"],
                "bit_identical": m["bit_identical"],
            } for n, m in mixed.items()}
        doc["entropy"] = {
            str(n): {
                "frames_per_s": v["gateway_fps"],
                "speedup_vs_per_frame": v["speedup"],
                "tick_ms": v["tick_ms"],
                "bit_identical": v["bit_identical"],
            } for n, v in result.items() if isinstance(n, int)}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the N=128 point")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: fewest iterations that still "
                         "exercise every assert")
    ap.add_argument("--shards", type=int, default=0,
                    help="also serve through the sharded dispatch plane "
                         "(per-device chains + shard-local ingest) over "
                         "this many forced host devices; merges into an "
                         "existing BENCH_gateway.json under shards[S]")
    args = ap.parse_args()
    if args.shards:
        from benchmarks.fleet_serve import force_host_devices
        force_host_devices(args.shards)
    out = run_all(quick=args.quick, shards=args.shards, smoke=args.smoke)
    print("wrote", write_bench_json(out, shards=args.shards))
