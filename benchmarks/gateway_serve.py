"""Gateway serving benchmark: k-bucketed batched dispatch vs the
per-frame ``SplitEngine.run`` loop (the seed's serving model).

N concurrent sessions each submit one frame per tick; the entropy
policy routes them into two k-buckets (easy -> fully local k=L, hard ->
shallow split k=2), so every tick is a handful of padded dispatches
instead of one 3-executable chain per frame.  Both paths deliver each
frame's embedding to its client as a host array — serving returns
results, so the baseline materializes per frame exactly like the
gateway's ``FrameResult``s do.

The encoder is a smoke-scale instance of the paper's model family: the
paper serves a small (~11M-param full-scale, ~0.1 GFLOP) streaming edge
CNN, which is exactly the regime where per-frame dispatch overhead, not
FLOPs, dominates the serving loop — the overhead k-bucketing amortizes.
(At CPU-server widths the per-frame loop is compute-bound instead and
the win shrinks to ~2-3x; both regimes share the same bit-parity
contract.)

Asserts that gateway embeddings are bit-identical to the per-frame path
before reporting any throughput number.

    PYTHONPATH=src python -m benchmarks.gateway_serve [--quick] [--shards S]

``--shards S`` additionally serves the same workload through a gateway
whose fleet data plane is a device-resident ``ShardedFleetBackend`` over
S forced host devices — same bit-parity contract, plus the measured
host->device ingest/snapshot traffic of the backend.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import row

ENC_KW = dict(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2), n_mels=16,
              frames=16, d_embed=32, groups=4)
SIZES = (8, 32, 128)
OFFLOAD_K = 2
THRESHOLD = 0.5


def _setup(n, *, shards=0):
    from repro.api import (ShardedFleetBackend, StreamSplitGateway,
                           make_policy)
    from repro.core.splitter import SplitEngine
    from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
    cfg = AudioEncCfg(**ENC_KW)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mels = rng.normal(size=(n, cfg.frames, cfg.n_mels)).astype(np.float32)
    # uncertainty spread straddling the threshold 50/50 — the cascade's
    # calibrated operating point (CascadeServer auto-calibrates its
    # threshold to a quantile of observed entropies for the same reason)
    us = rng.permutation(np.linspace(0.05, 0.95, n))
    policy = make_policy("entropy", cfg.n_blocks, threshold=THRESHOLD,
                         offload_k=OFFLOAD_K)
    obs = np.stack([us, np.zeros(n), np.zeros(n)], 1).astype(np.float32)
    ks = policy.decide(obs)
    if shards:
        from repro.launch.mesh import make_sessions_mesh
        backend = ShardedFleetBackend(capacity=n, window=16,
                                      dim=cfg.d_embed,
                                      mesh=make_sessions_mesh(shards))
    else:
        backend = None
    gw = StreamSplitGateway(cfg, params, policy=policy, capacity=n,
                            window=16, qos_reserve=0, backend=backend)
    sids = [gw.open_session().sid for _ in range(n)]
    return cfg, params, SplitEngine(cfg), gw, sids, mels, us, ks


def bench_gateway(n, *, iters, shards=0, baseline=True):
    """-> (per-frame f/s, gateway f/s, bit_identical, stats).  Same
    frames, same k assignment, both materializing every embedding.
    ``baseline=False`` skips the per-frame timing repetitions (the
    sharded lane reuses the numbers already measured) — the parity
    reference round still runs."""
    from repro.api import FrameRequest
    cfg, params, eng, gw, sids, mels, us, ks = _setup(n, shards=shards)

    def submit_all(t):
        for i, sid in enumerate(sids):
            gw.submit(sid, FrameRequest(t=t, mel=mels[i], u=float(us[i])))

    def per_frame_round():
        return [np.asarray(eng.run(params, mels[i:i + 1], int(ks[i]))[0])[0]
                for i in range(n)]

    # warmup: compile every executable both paths touch
    submit_all(0)
    results = gw.tick()
    z_ref = per_frame_round()

    # parity first: a fast wrong answer is not a result
    bit_identical = all((r.z == z_ref[i]).all() and r.k == ks[i]
                        for i, r in enumerate(results))

    # timeit-style best-of-repeats: the min time of each path suppresses
    # scheduler/contention noise (the batched path threads across cores,
    # so background load hits it disproportionately)
    pf_best, gw_best = float("inf"), float("inf")
    tick = 1
    for _ in range(5):
        if baseline:
            t0 = time.perf_counter()
            for _ in range(iters):
                per_frame_round()
            pf_best = min(pf_best, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _ in range(iters):
            submit_all(tick)
            gw.tick()
            tick += 1
        gw_best = min(gw_best, time.perf_counter() - t0)
    return n * iters / pf_best, n * iters / gw_best, bit_identical, \
        gw.stats()


def run_all(*, quick=False, shards=0):
    sizes = [n for n in SIZES if not (quick and n > 32)]
    result = {}
    for n in sizes:
        iters = max(4, 128 // n)
        pf, gwf, exact, _ = bench_gateway(n, iters=iters)
        assert exact, f"gateway embeddings diverged from per-frame at N={n}"
        speedup = gwf / pf
        result[n] = {"per_frame_fps": pf, "gateway_fps": gwf,
                     "speedup": speedup, "bit_identical": exact}
        row(f"gateway.per_frame.N{n}", 1e6 / pf, "frames/s baseline")
        row(f"gateway.bucketed.N{n}", 1e6 / gwf,
            f"{speedup:.1f}x vs per-frame, bit-identical")
        if shards and n % shards == 0:
            _, shf, exact_s, st = bench_gateway(n, iters=iters,
                                                shards=shards,
                                                baseline=False)
            assert exact_s, \
                f"sharded-backend embeddings diverged at N={n}"
            assert st.ingest_h2d_bytes == 0, \
                "device-resident ingest must not move embedding payload"
            result[n]["sharded_fps"] = shf
            result[n]["sharded"] = {
                "shards": st.shards, "shard_frames": st.shard_frames,
                "ingest_h2d_bytes": st.ingest_h2d_bytes,
                "snapshot_h2d_bytes": st.snapshot_h2d_bytes}
            row(f"gateway.bucketed.sharded{st.shards}.N{n}", 1e6 / shf,
                f"{shf / pf:.1f}x vs per-frame, bit-identical, ingest "
                f"payload h2d {st.ingest_h2d_bytes} B (device-resident)")
    print("BENCH " + json.dumps({"bench": "gateway_serve",
                                 "enc": ENC_KW["widths"],
                                 "threshold": THRESHOLD,
                                 "offload_k": OFFLOAD_K, **
                                 {str(k): v for k, v in result.items()}}))
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the N=128 point")
    ap.add_argument("--shards", type=int, default=0,
                    help="also serve through a device-resident "
                         "ShardedFleetBackend over this many forced "
                         "host devices")
    args = ap.parse_args()
    if args.shards:
        from benchmarks.fleet_serve import force_host_devices
        force_host_devices(args.shards)
    run_all(quick=args.quick, shards=args.shards)
