"""CPU-scale contrastive representation training on the synthetic stream —
the measurement substrate for Fig 8 (probe), Table 3 (retrieval) and
Table 5 (loss ablation under frame drops).

Modes:
  streamsplit  N=8 batches + GMM virtual negatives + hybrid (SWD+Lap)
  edge_only    N=8 batches, plain InfoNCE (the collapse-prone baseline)
  server       N=64 large-batch InfoNCE (upper bound)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gmm as G
from repro.core.hybrid import HybridCfg, hybrid_loss
from repro.core.infonce import (batch_infonce, infonce_with_virtual_negatives,
                                streaming_infonce)
from repro.data.audio_stream import AudioStream, StreamCfg, augment_pair
from repro.models.audio_encoder import AudioEncCfg, encode, init_audio_encoder
from repro.optim import adamw_init, adamw_update

ENC = AudioEncCfg(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2),
                  d_embed=32, groups=4, frames=97)


@dataclass
class TrainResult:
    params: dict
    eval_z: np.ndarray
    eval_y: np.ndarray
    probe_acc: float
    collapse: float   # mean pairwise |cos| of eval embeddings


def _encode(params, mel):
    return encode(ENC, params, mel)


def make_loss(mode, variant="hybrid", n_syn=16):
    # at this scale (d=32, N=8) SWD values are tiny; λ₁ rescaled accordingly
    hcfg = HybridCfg(lam_sw=2.0, lam_lap=0.01, n_dirs=32, knn=3)

    def loss_fn(params, key, m1, m2, gmm_state, mask, cold, zbuf):
        z1 = _encode(params, m1)
        z2 = _encode(params, m2)
        if mode in ("server", "edge_only"):
            return batch_infonce(z1, z2, tau=0.1), z1
        # streamsplit: virtual negatives decouple quality from batch size.
        # Cold start (paper §4.1.2): conservative local policy (batch
        # negatives) until the GMM sufficient statistics are populated.
        task_virtual = infonce_with_virtual_negatives(
            key, gmm_state, z1, z2, n_syn=n_syn, tau=0.1, boundary_tau=0.1)
        task_cold = batch_infonce(z1, z2, tau=0.1)
        # after cold start keep a symmetric real-negative anchor term: the
        # one-sided (stop-grad) virtual repulsion alone drifts (see
        # tests/test_infonce.py::test_stopgrad_negative_drift)
        task = jnp.where(cold, task_cold,
                         0.5 * task_cold + 0.5 * task_virtual)
        # ... + the server-side hybrid regularizers.  As on the server, the
        # SWD quantiles are estimated over the temporal BUFFER (current
        # frames + stop-gradient history), not the 8-frame batch.  The
        # buffer is stored newest-first; the Laplacian needs true temporal
        # order (oldest .. newest, then the current chronological batch) or
        # its edges connect random pairs and it becomes a collapse force.
        z_seq = jnp.concatenate([zbuf[::-1], z1], axis=0)
        buf_mask = jnp.concatenate([jnp.ones((zbuf.shape[0],)), mask])
        reg, _ = hybrid_loss(key, z_seq[None], hcfg, mask=buf_mask[None],
                             variant=variant)
        return task + reg, z1

    return loss_fn


def train_representation(mode="streamsplit", *, steps=250, batch=8,
                         drop_rate=0.0, variant="hybrid", seed=0,
                         eval_n=240, lr=2e-3, n_syn=16):
    key = jax.random.PRNGKey(seed)
    params = init_audio_encoder(ENC, key)
    opt = adamw_init(params)
    gmm = G.init_gmm(jax.random.PRNGKey(seed + 1), 16, ENC.d_embed)
    stream = AudioStream(StreamCfg(seed=seed))
    rng = np.random.default_rng(seed)
    loss_fn = make_loss(mode, variant, n_syn=n_syn)
    eff_batch = 64 if mode == "server" else batch

    zbuf = jnp.zeros((96, ENC.d_embed))
    zbuf = zbuf.at[:, 0].set(1.0)  # arbitrary unit vectors until filled

    @jax.jit
    def step(params, opt, key, m1, m2, gmm_state, mask, cold, zbuf):
        (l, z1), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, key, m1, m2, gmm_state, mask, cold, zbuf)
        params, opt = adamw_update(params, g, opt, lr=lr)
        return params, opt, l, z1

    for i in range(steps):
        mels, _, _ = stream.batch(eff_batch)
        m1s, m2s = [], []
        for m in mels:
            a, b = augment_pair(rng, m[: ENC.frames])
            m1s.append(a)
            m2s.append(b)
        m1 = jnp.asarray(np.stack(m1s))
        m2 = jnp.asarray(np.stack(m2s))
        mask = jnp.asarray(
            (rng.random(eff_batch) >= drop_rate).astype(np.float32))
        key, sub = jax.random.split(key)
        cold = jnp.bool_(i < 50)   # T_coldstart = 50 frames (paper §4.1.2)
        params, opt, l, z1 = step(params, opt, sub, m1, m2, gmm, mask, cold,
                                  zbuf)
        if mode == "streamsplit":
            zbuf = jnp.concatenate(
                [jax.lax.stop_gradient(z1), zbuf], 0)[: zbuf.shape[0]]
            # lazy sync (paper §4.3.3): the GMM is fit server-side on the
            # *temporal buffer* (diverse across the stream) and downlinked —
            # NOT on the edge's instantaneous 8-frame batch, which would
            # track any incipient collapse.
            gmm = G.em_update(gmm, zbuf, decay=0.1)

    # evaluation set
    ev = AudioStream(StreamCfg(seed=seed + 100))
    mels, ys, _ = ev.batch(eval_n)
    z = np.asarray(jax.jit(_encode)(params,
                                    jnp.asarray(mels[:, : ENC.frames])))
    acc = linear_probe(z, ys, seed=seed)
    sim = np.abs(z @ z.T)
    collapse = float((sim.sum() - eval_n) / (eval_n * (eval_n - 1)))
    return TrainResult(params, z, ys, acc, collapse)


def linear_probe(z, y, *, seed=0, train_frac=0.75, steps=300, lr=0.5):
    """Multinomial logistic probe on frozen embeddings."""
    n = len(y)
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    n_tr = int(n * train_frac)
    tr, te = idx[:n_tr], idx[n_tr:]
    n_cls = int(y.max()) + 1
    W = jnp.zeros((z.shape[1], n_cls))
    b = jnp.zeros((n_cls,))
    zt = jnp.asarray(z[tr])
    yt = jnp.asarray(y[tr])

    def loss(Wb):
        W, b = Wb
        logits = zt @ W + b
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yt[:, None], 1))

    Wb = (W, b)
    g_fn = jax.jit(jax.grad(loss))
    for _ in range(steps):
        g = g_fn(Wb)
        Wb = jax.tree.map(lambda p, g: p - lr * g, Wb, g)
    W, b = Wb
    pred = np.asarray(jnp.argmax(jnp.asarray(z[te]) @ W + b, -1))
    return float((pred == y[te]).mean())


def retrieval_metrics(z, y, *, k=10):
    """mAP@k and R@1 with cosine similarity (Table 3)."""
    zn = z / np.maximum(np.linalg.norm(z, axis=1, keepdims=True), 1e-9)
    sim = zn @ zn.T
    np.fill_diagonal(sim, -np.inf)
    order = np.argsort(-sim, axis=1)[:, :k]
    rel = (y[order] == y[:, None]).astype(float)
    # mAP@k
    prec = np.cumsum(rel, 1) / np.arange(1, k + 1)[None]
    denom = np.maximum(rel.sum(1), 1)
    ap = (prec * rel).sum(1) / denom
    return float(ap.mean()), float(rel[:, 0].mean())
