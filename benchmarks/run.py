"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (value semantics per row name:
KB, ms, mJ, %, correlation r, ... — the derived column carries the paper's
number for side-by-side comparison), and writes the machine-readable
serving-perf trajectories CI uploads as artifacts so performance is
tracked across PRs: ``BENCH_gateway.json`` (frames/s, syncs/tick, staged
H2D bytes, p50/p95 tick latency at N ∈ {32, 64}; docs/PERF.md),
``BENCH_stream.json`` (sustained streaming frames/s, per-class p95 queue
waits, deadline-miss rates, preemption counts, syncs/tick;
docs/STREAMING.md), ``BENCH_cluster.json`` (federation drain lane:
migration pause p50/p95 ms, frames/s before/during/after a live drain,
migrated volume; docs/FEDERATION.md), and ``BENCH_obs.json`` (telemetry
plane: asserted <2% tracing-off overhead, schema-validated Prometheus
export, flight-recorder exactness; docs/OBSERVABILITY.md).

    PYTHONPATH=src python -m benchmarks.run [--quick|--smoke] [--only PREFIX]

``--smoke`` is the CI configuration: the fewest iterations that still
exercise every bit-parity assert (a benchmark whose parity assert trips
fails the process loudly — that is the point of running it in CI).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only benches whose module matches")
    ap.add_argument("--quick", action="store_true",
                    help="skip the slowest (training-based) benches")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (implies --quick)")
    args = ap.parse_args()
    quick = args.quick or args.smoke

    from benchmarks import (cluster_serve, fleet_serve, gateway_serve,
                            kernels_bench, obs_bench, quality_tables,
                            stream_serve, system_tables)
    print("name,us_per_call,derived")
    t0 = time.time()

    def gateway():
        out = gateway_serve.run_all(quick=quick, smoke=args.smoke)
        path = gateway_serve.write_bench_json(out)
        print(f"# wrote {path}", file=sys.stderr)

    def stream():
        out = stream_serve.run_all(quick=quick, smoke=args.smoke)
        path = stream_serve.write_bench_json(out)
        print(f"# wrote {path}", file=sys.stderr)

    def cluster():
        out = cluster_serve.run_all(quick=quick, smoke=args.smoke)
        path = cluster_serve.write_bench_json(out)
        print(f"# wrote {path}", file=sys.stderr)

    def obs():
        out = obs_bench.run_all(quick=quick, smoke=args.smoke)
        path = obs_bench.write_bench_json(out)
        print(f"# wrote {path}", file=sys.stderr)

    suites = [("system", system_tables.run_all),
              ("kernels", kernels_bench.run_all),
              ("fleet", lambda: fleet_serve.run_all(quick=quick)),
              ("gateway", gateway),
              ("stream", stream),
              ("cluster", cluster),
              ("obs", obs)]
    if not quick:
        suites.insert(1, ("quality", quality_tables.run_all))
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        fn()
    print(f"# total {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
