"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (value semantics per row name:
KB, ms, mJ, %, correlation r, ... — the derived column carries the paper's
number for side-by-side comparison).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only PREFIX]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only benches whose module matches")
    ap.add_argument("--quick", action="store_true",
                    help="skip the slowest (training-based) benches")
    args = ap.parse_args()

    from benchmarks import (fleet_serve, gateway_serve, kernels_bench,
                            quality_tables, system_tables)
    print("name,us_per_call,derived")
    t0 = time.time()
    suites = [("system", system_tables.run_all),
              ("kernels", kernels_bench.run_all),
              ("fleet", lambda: fleet_serve.run_all(quick=args.quick)),
              ("gateway", lambda: gateway_serve.run_all(quick=args.quick))]
    if not args.quick:
        suites.insert(1, ("quality", quality_tables.run_all))
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        fn()
    print(f"# total {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
