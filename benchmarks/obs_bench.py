"""Observability-plane benchmark: the tracing-off overhead budget,
exporter schema validation, and the flight-recorder exactness contract.

**Lane 1 — tracing-off overhead (< 2%, asserted).**  The telemetry
plane's pinned contract is that DISABLED tracing costs the hot path
nothing but attribute tests (docs/OBSERVABILITY.md): the submit path
pays one ``Tracer.maybe_begin`` miss, and every later hop pays one
``qf.trace is not None`` check.  An fps A/B against "the same code
without the branches" does not exist (the branches ARE the code) and a
2% fps delta is under CI noise anyway — so the lane measures the
off-path work DIRECTLY (microbenched per-frame: one miss + one
attribute test per stamp site) and asserts it is < 2% of the measured
per-frame serve time.  On any machine the miss is tens of nanoseconds
against a multi-hundred-microsecond frame, so a regression here means
someone put real work on the disabled path — exactly what the lane
exists to catch.  The fps of the SAME workload with ``sample=1.0`` is
reported beside it (tracing-ON cost is allowed to be visible; it buys
per-frame spans).

**Lane 2 — exporter schema (asserted).**  The off lane's server (and
its gateway, sharing the registry) exports through
``StreamServer.metrics()``; ``validate_prometheus`` must accept the
text (name/label grammar, TYPE-before-sample, no duplicate series) and
the sample count must cover the per-class serving counters.  A
registry JSONL snapshot is appended beside the run's own scalars
through ``MetricsLogger`` — the two sinks share one file format.

**Lane 3 — flight-recorder exactness (asserted).**  A deterministic
fake-clock overload sheds a known number of BULK frames; the
recorder's cumulative counts must reconstruct the stats-view shed
books exactly, and with ``sample=1.0`` every shed frame's span must
end at its ``shed`` stamp.  This is the stepped-clock miniature of the
cluster's automatic failover dump (tests/test_obs.py pins that end).

    PYTHONPATH=src python -m benchmarks.obs_bench [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import row
from benchmarks.gateway_serve import DEEP_KW, MixedKPolicy

N = 16
WARMUP_ROUNDS = 2
# hops that test ``qf.trace is not None`` on the serving path when
# tracing is off: enqueue, stage, admit, dispatch, collect (promote /
# preempt / shed only run on their anomaly paths)
_STAMP_SITES = 5
OVERHEAD_BUDGET = 0.02


def _build(n, rounds_total):
    from repro.api import FrameRequest
    from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
    cfg = AudioEncCfg(**DEEP_KW)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    us = rng.permutation(np.linspace(0.02, 0.98, n))
    frames = [[FrameRequest(
        t=t, mel=rng.normal(size=(cfg.frames, cfg.n_mels)).astype(
            np.float32), u=float(us[i]))
        for i in range(n)] for t in range(rounds_total)]
    return cfg, params, frames


def _server(cfg, params, n, *, sample):
    from repro.api import StreamSplitGateway
    from repro.serving import SchedulerCfg, StreamServer
    gw = StreamSplitGateway(cfg, params,
                            policy=MixedKPolicy(cfg.n_blocks),
                            capacity=n, window=16, qos_reserve=0)
    return StreamServer(gw, cfg=SchedulerCfg(max_batch=n),
                        queue_maxlen=1 << 16, trace_sample=sample)


def _off_path_ns():
    """Measured cost of the disabled tracing path, per frame: one
    ``maybe_begin`` miss at submit + one attribute test per stamp
    site.  Deterministic (pure Python, no device)."""
    from repro.obs import Tracer
    from repro.serving.queues import QueuedFrame
    tr = Tracer(0.0)
    qf = QueuedFrame(sid=1, frame=None, qos=None, seq=0, enq_s=0.0,
                     deadline_s=0.0)
    reps = 200_000
    t0 = time.perf_counter()
    for i in range(reps):
        tr.maybe_begin(1, i)
    begin_ns = (time.perf_counter() - t0) / reps * 1e9
    t0 = time.perf_counter()
    for _ in range(reps):
        if qf.trace is not None:
            raise AssertionError
    check_ns = (time.perf_counter() - t0) / reps * 1e9
    return begin_ns + _STAMP_SITES * check_ns, begin_ns, check_ns


def bench_overhead(n=N, *, rounds=16, repeats=3):
    """-> lane-1 dict: off-path ns/frame vs serve time/frame, plus the
    off/on fps A/B of the same stepped workload."""
    rounds_total = WARMUP_ROUNDS + rounds * repeats
    cfg, params, frames = _build(n, rounds_total)
    lanes = {"off": _server(cfg, params, n, sample=0.0),
             "on": _server(cfg, params, n, sample=1.0)}
    sids = {name: [srv.open_session().sid for _ in range(n)]
            for name, srv in lanes.items()}
    best = {name: float("inf") for name in lanes}

    def pump(name, t):
        srv = lanes[name]
        for i, sid in enumerate(sids[name]):
            srv.submit(sid, frames[t][i])
        srv.step()
        while srv.busy():
            srv.step()

    for t in range(WARMUP_ROUNDS):          # compile both paths
        for name in lanes:
            pump(name, t)
    t_base = WARMUP_ROUNDS
    for _ in range(repeats):                # interleaved best-of
        for name in lanes:
            t0 = time.perf_counter()
            for t in range(t_base, t_base + rounds):
                pump(name, t)
            best[name] = min(best[name], time.perf_counter() - t0)
        t_base += rounds
    fps = {name: n * rounds / b for name, b in best.items()}

    off = lanes["off"]
    assert off.tracer.started == 0 and off.recorder.traces() == [], \
        "sample=0.0 must allocate no spans"
    on = lanes["on"]
    assert on.tracer.started == on.tracer.finished == rounds_total * n

    off_ns, begin_ns, check_ns = _off_path_ns()
    frame_ns = 1e9 / fps["off"]
    frac = off_ns / frame_ns
    assert frac < OVERHEAD_BUDGET, (
        f"disabled tracing costs {frac:.2%} of a frame "
        f"({off_ns:.0f}ns of {frame_ns:.0f}ns) — budget "
        f"{OVERHEAD_BUDGET:.0%}")
    return {
        "n": n,
        "frames_per_s": fps,
        "tracing_on_cost": 1.0 - fps["on"] / fps["off"],
        "off_path_ns_per_frame": off_ns,
        "off_maybe_begin_ns": begin_ns,
        "off_attr_check_ns": check_ns,
        "off_path_fraction_of_frame": frac,
        "overhead_budget": OVERHEAD_BUDGET,
        "traces_on": on.tracer.finished,
        "server_off": off,                 # lane 2 exports this stack
    }


def bench_export(srv):
    """-> lane-2 dict: Prometheus text validated + snapshot shape."""
    from repro.obs import registry_snapshot, validate_prometheus
    text = srv.metrics()
    n_samples = validate_prometheus(text)   # raises on any violation
    assert n_samples >= 20, f"suspiciously thin export: {n_samples}"
    for must in ("stream_frames_served", "stream_frames_submitted",
                 "stream_queue_wait_ms_count", "gateway_stage_ewma_ms"):
        assert must in text, f"export lost {must}"
    snap = registry_snapshot(srv.registry)
    assert {m["kind"] for m in snap["metrics"]} >= {"counter", "gauge",
                                                    "histogram"}
    return {"prometheus_samples": n_samples,
            "registry_metrics": len(snap["metrics"]),
            "prometheus_valid": True}


def bench_recorder(*, rounds=24, max_batch=4):
    """-> lane-3 dict: fake-clock overload; dump counts == stats books,
    exactly."""
    from repro.api import FrameRequest, QoSClass, StreamSplitGateway
    from repro.api.policies import FixedKPolicy
    from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
    from repro.serving import SchedulerCfg, StreamServer
    B = QoSClass.BULK
    cfg = AudioEncCfg(**DEEP_KW)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))

    class _FakeClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

    clock = _FakeClock()
    gw = StreamSplitGateway(cfg, params,
                            policy=FixedKPolicy(cfg.n_blocks, 4),
                            capacity=4, window=16, qos_reserve=0,
                            clock=clock)
    srv = StreamServer(gw, cfg=SchedulerCfg(
        max_batch=max_batch, deadline_ms={B: 100.0},
        shed_horizon_ms=200.0, max_wait_ms={B: None}),
        clock=clock, trace_sample=1.0)
    sid = srv.open_session(qos=B).sid
    rng = np.random.default_rng(3)
    mels = [rng.normal(size=(cfg.frames, cfg.n_mels)).astype(np.float32)
            for _ in range(8)]
    # each round: offer 2x the batch, serve one tick, jump the clock a
    # full horizon — everything still queued at the next admit sheds
    for r in range(rounds):
        for j in range(2 * max_batch):
            srv.submit(sid, FrameRequest(t=r * 2 * max_batch + j,
                                         mel=mels[j % 8]))
        srv.step()
        clock.t += 0.5
    while srv.busy():
        srv.step()
        clock.t += 0.5
    st = srv.stats()
    dump = srv.dump_trace(reason="obs_bench")
    assert st.shed_expired["bulk"] > 0, "overload lane must shed"
    assert dump["counts"]["shed"] == st.shed_expired["bulk"], \
        "flight recorder disagrees with the conservation books"
    # a shed counts as the deadline miss it already was in the stats
    # view, but records as a "shed" event — the two ledgers partition
    assert (dump["counts"].get("deadline_miss", 0)
            + dump["counts"]["shed"]) == st.deadline_misses["bulk"]
    shed_spans = [t for t in dump["traces"]
                  if t["events"][-1]["name"] == "shed"]
    assert len(shed_spans) == st.shed_expired["bulk"], \
        "every shed frame's span must end at its shed stamp"
    return {"rounds": rounds,
            "shed": st.shed_expired["bulk"],
            "served": st.frames_served["bulk"],
            "dump_counts": dump["counts"],
            "evicted_events": dump["evicted_events"],
            "counts_exact": True}


def run_all(*, quick=False, smoke=False):
    result = {}
    rounds = 6 if smoke else (10 if quick else 16)
    o = bench_overhead(N, rounds=rounds, repeats=2 if smoke else 3)
    srv_off = o.pop("server_off")
    result["overhead"] = o
    row("obs.off_path_ns_per_frame", o["off_path_ns_per_frame"] * 1e-3,
        f"{o['off_path_fraction_of_frame']:.4%} of a frame "
        f"(budget {o['overhead_budget']:.0%}), asserted")
    row(f"obs.tracing_on.N{N}", 1e6 / o["frames_per_s"]["on"],
        f"tracing-on cost {o['tracing_on_cost']:.1%} of throughput, "
        f"{o['traces_on']} spans retired")
    e = bench_export(srv_off)
    result["export"] = e
    row("obs.prometheus_samples", e["prometheus_samples"],
        "schema-validated exposition samples from one serving stack")
    with srv_off.queues.cond:
        pass                               # stack idle; nothing to join
    r = bench_recorder(rounds=8 if smoke else 24)
    result["recorder"] = r
    row("obs.recorder_shed", r["shed"],
        f"dump counts == stats books exactly; "
        f"{r['evicted_events']} ring-evicted events still counted")
    # one JSONL line carrying the registry beside the bench scalars —
    # the composed-sinks pattern docs/OBSERVABILITY.md describes
    from repro.obs import write_jsonl
    from repro.runtime.metrics import MetricsLogger
    with MetricsLogger("BENCH_obs.jsonl", window=8) as m:
        m.log(0, off_path_ns=o["off_path_ns_per_frame"],
              fps_off=o["frames_per_s"]["off"],
              fps_on=o["frames_per_s"]["on"])
    write_jsonl(srv_off.registry, "BENCH_obs.jsonl", step=1)
    print("BENCH " + json.dumps({"bench": "obs", **result}))
    return result


def write_bench_json(result, path="BENCH_obs.json"):
    """Machine-readable observability trajectory (CI artifact — see
    docs/OBSERVABILITY.md for the schema)."""
    doc = {"bench": "obs", "schema": 1,
           "backend": jax.default_backend(), **result}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: fewest rounds that still "
                         "exercise every assert")
    args = ap.parse_args()
    out = run_all(quick=args.quick, smoke=args.smoke)
    print("wrote", write_bench_json(out))
