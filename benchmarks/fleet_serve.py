"""Fleet serving benchmark: batched multi-session refinement and cascade
serving throughput vs. fleet size.

Measures, for N ∈ {1, 8, 32, 128} concurrent sessions:

- refine-steps/sec — one vmapped ``FleetRefiner.refine`` over the packed
  ``(N, W, d)`` fleet vs. N sequential ``ServerRefiner.refine`` calls
  (the seed's serving model: one dispatch per session);
- sessions/sec   — end-to-end admission → ingest → batched refine;
- requests/sec   — the batched two-sub-batch ``CascadeServer.handle``.

Prints the standard ``name,us_per_call,derived`` CSV rows plus one
``BENCH {...}`` JSON line for machine consumption.

    PYTHONPATH=src python -m benchmarks.fleet_serve [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import row

W, DIM, N_CLASSES = 100, 64, 10
SIZES = (1, 8, 32, 128)


def _head():
    def head_init(key):
        return {"w": 0.01 * jax.random.normal(key, (DIM, N_CLASSES))}

    def head_apply(p, z):
        return z @ p["w"]

    return head_init, head_apply


def _fill(insert, rng, *, drop=0.1):
    """Ingest W frames with ~10% network drops through `insert(t, z, label)`."""
    for t in range(W):
        if rng.random() < drop:
            continue
        insert(t, rng.normal(size=DIM).astype(np.float32), t % N_CLASSES)


def bench_refine(n, *, iters):
    """-> (sequential steps/s, fleet steps/s).  A "step" is one session's
    refinement; both paths share identical buffer contents."""
    from repro.core.fleet import FleetBuffer, FleetRefiner
    from repro.core.server import ServerRefiner, TemporalBuffer
    head_init, head_apply = _head()

    buffers = []
    fleet = FleetBuffer(capacity=n, window=W, dim=DIM)
    for i in range(n):
        rng = np.random.default_rng(i)
        buf = TemporalBuffer(window=W, dim=DIM)
        _fill(lambda t, z, l: buf.insert(t, z, label=l), rng)
        buffers.append(buf)
        sid = fleet.admit()
        rng = np.random.default_rng(i)
        _fill(lambda t, z, l: fleet.insert(sid, t, z, label=l), rng)

    srv = ServerRefiner(head_init, head_apply, lr=1e-2)
    flt = FleetRefiner(head_init, head_apply, lr=1e-2)

    def seq_round(i):
        for buf in buffers:
            srv.refine(jax.random.PRNGKey(i), buf)

    def fleet_round(i):
        flt.refine(jax.random.PRNGKey(i), fleet)

    out = []
    for fn in (seq_round, fleet_round):
        fn(0)                                   # warmup: compile
        t0 = time.perf_counter()
        for i in range(iters):
            fn(1 + i)
        dt = time.perf_counter() - t0
        out.append(n * iters / dt)
    return out


def bench_sessions(n, *, iters):
    """End-to-end fleet lifecycle: admit → ingest (batched) → refine →
    evict.  -> sessions/sec."""
    from repro.core.fleet import FleetBuffer, FleetRefiner
    head_init, head_apply = _head()
    fleet = FleetBuffer(capacity=n, window=W, dim=DIM)
    flt = FleetRefiner(head_init, head_apply, lr=1e-2)
    rng = np.random.default_rng(0)

    def lifecycle(i):
        sids = np.array([fleet.admit() for _ in range(n)])
        for t in range(W):
            keep = rng.random(n) > 0.1
            if keep.any():
                fleet.insert_batch(sids[keep], np.full(keep.sum(), t),
                                   rng.normal(size=(int(keep.sum()), DIM)),
                                   np.full(keep.sum(), t % N_CLASSES))
        flt.refine(jax.random.PRNGKey(i), fleet)
        for sid in sids:
            fleet.evict(sid)

    lifecycle(0)
    t0 = time.perf_counter()
    for i in range(iters):
        lifecycle(1 + i)
    return n * iters / (time.perf_counter() - t0)


def bench_cascade(batch, *, iters, seq=32):
    """Batched cascade serving -> requests/sec."""
    from dataclasses import replace
    from repro.configs.base import get_config, smoke_config
    from repro.launch.serve import CascadeServer
    from repro.models import lm
    small = smoke_config(get_config("qwen1.5-0.5b"))
    large = replace(smoke_config(get_config("qwen3-1.7b")),
                    vocab=small.vocab, d_model=small.d_model, n_layers=4)
    key = jax.random.PRNGKey(0)
    sp, _ = lm.init_lm(small, key)
    lp, _ = lm.init_lm(large, key)
    srv = CascadeServer(small, sp, large, lp, threshold="auto")
    toks = [jax.random.randint(jax.random.PRNGKey(i), (batch, seq), 0,
                               small.vocab) for i in range(iters + 1)]
    srv.handle(toks[0])
    t0 = time.perf_counter()
    for t in toks[1:]:
        srv.handle(t)
    return batch * iters / (time.perf_counter() - t0)


def run_all(*, quick=False):
    sizes = [n for n in SIZES if not (quick and n > 32)]
    result = {"refine": {}, "sessions": {}, "cascade": {}}
    for n in sizes:
        iters = max(3, 96 // n)
        seq_sps, fleet_sps = bench_refine(n, iters=iters)
        speedup = fleet_sps / seq_sps
        result["refine"][n] = {"sequential_steps_per_s": seq_sps,
                               "fleet_steps_per_s": fleet_sps,
                               "speedup": speedup}
        row(f"fleet.refine.seq.N{n}", 1e6 / seq_sps, "steps/s baseline")
        row(f"fleet.refine.batched.N{n}", 1e6 / fleet_sps,
            f"{speedup:.1f}x vs sequential")
    for n in sizes:
        sps = bench_sessions(n, iters=max(2, 16 // n))
        result["sessions"][n] = {"sessions_per_s": sps}
        row(f"fleet.lifecycle.N{n}", 1e6 / sps, "admit+ingest+refine+evict")
    for b in sizes:
        rps = bench_cascade(b, iters=max(3, 48 // b))
        result["cascade"][b] = {"requests_per_s": rps}
        row(f"fleet.cascade.B{b}", 1e6 / rps, "two-tier batched handle")
    print("BENCH " + json.dumps({"bench": "fleet_serve", "window": W,
                                 "dim": DIM, **result}))
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the N=128 points")
    args = ap.parse_args()
    run_all(quick=args.quick)
