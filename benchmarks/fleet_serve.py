"""Fleet serving benchmark: batched multi-session refinement, the
host-vs-device-resident data plane, and cascade serving throughput vs.
fleet size.

Measures, for N ∈ {1, 8, 32, 128} concurrent sessions:

- refine-steps/sec — one vmapped ``FleetRefiner.refine`` over the packed
  ``(N, W, d)`` fleet vs. N sequential ``ServerRefiner.refine`` calls
  (the seed's serving model: one dispatch per session);
- backend rounds/sec — one serving round (batched ingest + fleet refine)
  through ``HostFleetBackend`` (numpy rings, full snapshot copied to the
  device every round) vs ``ShardedFleetBackend`` (device-resident rings
  over the ``sessions`` mesh, donated in-place ingest, shard_map refine).
  Reports per-shard refine throughput, mean/p50/p95 round latency
  (measured after an explicit warmup round so XLA compile never pollutes
  the numbers), and the measured host->device traffic: the sharded plane
  moves **zero** snapshot bytes per round;
- sessions/sec   — end-to-end admission → ingest → batched refine;
- requests/sec   — the batched two-sub-batch ``CascadeServer.handle``.

Prints the standard ``name,us_per_call,derived`` CSV rows plus one
``BENCH {...}`` JSON line for machine consumption.

    PYTHONPATH=src python -m benchmarks.fleet_serve [--quick] [--shards S]

``--shards S`` forces S host (CPU) devices (the env must not have
initialized jax yet — run as shown above) and shards the session axis
S ways.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import pcts, row

W, DIM, N_CLASSES = 100, 64, 10
SIZES = (1, 8, 32, 128)


def _head():
    def head_init(key):
        return {"w": 0.01 * jax.random.normal(key, (DIM, N_CLASSES))}

    def head_apply(p, z):
        return z @ p["w"]

    return head_init, head_apply


def _fill(insert, rng, *, drop=0.1):
    """Ingest W frames with ~10% network drops through `insert(t, z, label)`."""
    for t in range(W):
        if rng.random() < drop:
            continue
        insert(t, rng.normal(size=DIM).astype(np.float32), t % N_CLASSES)


def bench_refine(n, *, iters):
    """-> (sequential steps/s, fleet steps/s).  A "step" is one session's
    refinement; both paths share identical buffer contents."""
    from repro.core.fleet import FleetBuffer, FleetRefiner
    from repro.core.server import ServerRefiner, TemporalBuffer
    head_init, head_apply = _head()

    buffers = []
    fleet = FleetBuffer(capacity=n, window=W, dim=DIM)
    for i in range(n):
        rng = np.random.default_rng(i)
        buf = TemporalBuffer(window=W, dim=DIM)
        _fill(lambda t, z, l: buf.insert(t, z, label=l), rng)
        buffers.append(buf)
        sid = fleet.admit()
        rng = np.random.default_rng(i)
        _fill(lambda t, z, l: fleet.insert(sid, t, z, label=l), rng)

    srv = ServerRefiner(head_init, head_apply, lr=1e-2)
    flt = FleetRefiner(head_init, head_apply, lr=1e-2)

    def seq_round(i):
        for buf in buffers:
            srv.refine(jax.random.PRNGKey(i), buf)

    def fleet_round(i):
        flt.refine(jax.random.PRNGKey(i), fleet)

    out = []
    for fn in (seq_round, fleet_round):
        fn(0)                                   # warmup: compile
        t0 = time.perf_counter()
        for i in range(iters):
            fn(1 + i)
        dt = time.perf_counter() - t0
        out.append(n * iters / dt)
    return out


def bench_backends(n, *, iters, shards=1):
    """Host vs device-resident sharded data plane.

    One serving *round* = batched ingest of one frame per session +
    one fleet-wide refine.  The host path re-snapshots the whole
    ``(N, W, d)`` fleet to the device every round; the sharded path
    refines the rings where they already live (``snapshot_h2d == 0``) —
    the per-round traffic is measured off the backend counters, not
    assumed."""
    from repro.core.fleet import HostFleetBackend, ShardedFleetBackend
    from repro.launch.mesh import make_sessions_mesh
    head_init, head_apply = _head()
    out = {}
    for kind in ("host", "sharded"):
        if kind == "host":
            b = HostFleetBackend(capacity=n, window=W, dim=DIM,
                                 head_init=head_init, head_apply=head_apply,
                                 lr=1e-2)
        else:
            # pin the mesh to the requested shard count (NOT every
            # visible device: the env may force more than --shards)
            b = ShardedFleetBackend(capacity=n, window=W, dim=DIM,
                                    head_init=head_init,
                                    head_apply=head_apply, lr=1e-2,
                                    mesh=make_sessions_mesh(shards))
        rng = np.random.default_rng(0)
        sids = np.array([b.admit() for _ in range(n)])
        for t in range(W):                       # pre-fill, ~10% drops
            keep = rng.random(n) > 0.1
            if keep.any():
                m = int(keep.sum())
                b.insert_batch(sids[keep], np.full(m, t),
                               rng.normal(size=(m, DIM)).astype(np.float32),
                               np.full(m, t % N_CLASSES))

        def round_(i, t):
            b.insert_batch(sids, np.full(n, t),
                           rng.normal(size=(n, DIM)).astype(np.float32),
                           np.full(n, t % N_CLASSES))
            b.refine(jax.random.PRNGKey(i))

        # warmup: compile BOTH the full-batch ingest scatter and the
        # refine step before anything is timed
        round_(0, W)
        snap0, ing0 = b.snapshot_h2d_bytes, b.ingest_h2d_bytes
        round_ms = []
        t0 = time.perf_counter()
        for i in range(iters):
            t1 = time.perf_counter()
            round_(1 + i, W + 1 + i)
            round_ms.append((time.perf_counter() - t1) * 1e3)
        rounds_s = iters / (time.perf_counter() - t0)
        snap_rd = (b.snapshot_h2d_bytes - snap0) // iters
        ing_rd = (b.ingest_h2d_bytes - ing0) // iters
        round_pcts = pcts(round_ms)
        p50, p95 = round_pcts["p50"], round_pcts["p95"]
        out[kind] = {
            "shards": b.shards,
            "rounds_per_s": rounds_s,
            "session_steps_per_s": n * rounds_s,
            "per_shard_sessions": n // b.shards,
            "per_shard_steps_per_s": n // b.shards * rounds_s,
            "round_ms": round_pcts,
            "snapshot_h2d_bytes_per_round": snap_rd,
            "ingest_h2d_bytes_per_round": ing_rd,
        }
        tag = f"sharded{b.shards}" if kind == "sharded" else "host"
        row(f"fleet.backend.{tag}.N{n}", 1e6 / rounds_s,
            f"{n // b.shards * rounds_s:.1f} steps/s/shard, round p50 "
            f"{p50:.2f}ms p95 {p95:.2f}ms, snapshot h2d {snap_rd} B/round")
    assert out["sharded"]["snapshot_h2d_bytes_per_round"] == 0, \
        "device-resident refine must not copy the fleet snapshot"
    assert out["host"]["snapshot_h2d_bytes_per_round"] > 0
    return out


def bench_sessions(n, *, iters):
    """End-to-end fleet lifecycle: admit → ingest (batched) → refine →
    evict.  -> sessions/sec."""
    from repro.core.fleet import FleetBuffer, FleetRefiner
    head_init, head_apply = _head()
    fleet = FleetBuffer(capacity=n, window=W, dim=DIM)
    flt = FleetRefiner(head_init, head_apply, lr=1e-2)
    rng = np.random.default_rng(0)

    def lifecycle(i):
        sids = np.array([fleet.admit() for _ in range(n)])
        for t in range(W):
            keep = rng.random(n) > 0.1
            if keep.any():
                fleet.insert_batch(sids[keep], np.full(keep.sum(), t),
                                   rng.normal(size=(int(keep.sum()), DIM)),
                                   np.full(keep.sum(), t % N_CLASSES))
        flt.refine(jax.random.PRNGKey(i), fleet)
        for sid in sids:
            fleet.evict(sid)

    lifecycle(0)
    t0 = time.perf_counter()
    for i in range(iters):
        lifecycle(1 + i)
    return n * iters / (time.perf_counter() - t0)


def bench_cascade(batch, *, iters, seq=32):
    """Batched cascade serving -> requests/sec."""
    from dataclasses import replace
    from repro.configs.base import get_config, smoke_config
    from repro.launch.serve import CascadeServer
    from repro.models import lm
    small = smoke_config(get_config("qwen1.5-0.5b"))
    large = replace(smoke_config(get_config("qwen3-1.7b")),
                    vocab=small.vocab, d_model=small.d_model, n_layers=4)
    key = jax.random.PRNGKey(0)
    sp, _ = lm.init_lm(small, key)
    lp, _ = lm.init_lm(large, key)
    srv = CascadeServer(small, sp, large, lp, threshold="auto")
    toks = [jax.random.randint(jax.random.PRNGKey(i), (batch, seq), 0,
                               small.vocab) for i in range(iters + 1)]
    srv.handle(toks[0])
    t0 = time.perf_counter()
    for t in toks[1:]:
        srv.handle(t)
    return batch * iters / (time.perf_counter() - t0)


def run_all(*, quick=False, shards=1):
    sizes = [n for n in SIZES if not (quick and n > 32)]
    result = {"refine": {}, "sessions": {}, "cascade": {}, "backends": {},
              "shards": shards}
    for n in sizes:
        iters = max(3, 96 // n)
        seq_sps, fleet_sps = bench_refine(n, iters=iters)
        speedup = fleet_sps / seq_sps
        result["refine"][n] = {"sequential_steps_per_s": seq_sps,
                               "fleet_steps_per_s": fleet_sps,
                               "speedup": speedup}
        row(f"fleet.refine.seq.N{n}", 1e6 / seq_sps, "steps/s baseline")
        row(f"fleet.refine.batched.N{n}", 1e6 / fleet_sps,
            f"{speedup:.1f}x vs sequential")
    for n in sizes:
        if n % max(shards, 1):
            continue                     # capacity must divide the mesh
        result["backends"][n] = bench_backends(n, iters=max(3, 48 // n),
                                               shards=shards)
    for n in sizes:
        sps = bench_sessions(n, iters=max(2, 16 // n))
        result["sessions"][n] = {"sessions_per_s": sps}
        row(f"fleet.lifecycle.N{n}", 1e6 / sps, "admit+ingest+refine+evict")
    for b in sizes:
        rps = bench_cascade(b, iters=max(3, 48 // b))
        result["cascade"][b] = {"requests_per_s": rps}
        row(f"fleet.cascade.B{b}", 1e6 / rps, "two-tier batched handle")
    print("BENCH " + json.dumps({"bench": "fleet_serve", "window": W,
                                 "dim": DIM, **result}))
    return result


def force_host_devices(n):
    """Force ``n`` fake host devices for the ``sessions`` mesh.

    Must run before jax initializes its backend (importing jax is fine;
    querying devices is not) — both serving benchmarks call this from
    ``__main__`` before any device touch."""
    import os
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())
    if len(jax.devices()) < n:
        raise SystemExit(
            f"--shards {n} needs {n} devices but jax initialized with "
            f"{len(jax.devices())}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} in the "
            "environment instead")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the N=128 points")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the session axis over this many forced "
                         "host devices (ShardedFleetBackend)")
    args = ap.parse_args()
    force_host_devices(args.shards)
    run_all(quick=args.quick, shards=args.shards)
