"""Kernel microbenchmarks (§5 overheads).

NOTE: Pallas kernels execute in interpret mode on this CPU container (the
TPU is the target, not the runtime), so wall times here measure the jnp
reference implementations and the interpreted kernel bodies — the paper-
comparable numbers are the jnp paths; kernel wall times are correctness
artifacts, not perf claims (the perf claims live in EXPERIMENTS.md
§Roofline, derived from the compiled dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us
from repro.core.swd import random_directions, sphere_prior_samples
from repro.kernels import ops, ref


def run_all():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    B, d, C, N, M = 256, 128, 64, 256, 50
    z = jax.random.normal(ks[0], (B, d))
    z = z / jnp.linalg.norm(z, axis=-1, keepdims=True)
    mu = 0.5 * jax.random.normal(ks[1], (C, d))
    var = jax.random.uniform(ks[2], (C, d), minval=0.05, maxval=0.5)
    logpi = jax.nn.log_softmax(jax.random.normal(ks[3], (C,)))
    zp = z + 0.05 * jax.random.normal(ks[4], (B, d))
    zp = zp / jnp.linalg.norm(zp, axis=-1, keepdims=True)
    zn = jax.random.normal(ks[5], (B, N, d))
    zn = zn / jnp.linalg.norm(zn, axis=-1, keepdims=True)

    gmm_ref = jax.jit(ref.gmm_posterior_ref)
    row("kernel_gmm_posterior_ref_jnp",
        time_us(gmm_ref, z, mu, var, logpi), f"B={B},C={C},d={d}")
    row("kernel_gmm_posterior_pallas_interp",
        time_us(lambda *a: ops.gmm_posterior(*a), z, mu, var, logpi),
        "interpret mode (CPU correctness path)")

    inf_ref = jax.jit(lambda a, b, c: ref.infonce_vneg_ref(a, b, c, 0.1))
    row("kernel_infonce_vneg_ref_jnp", time_us(inf_ref, z, zp, zn),
        f"paper GMM-synthesis class: 0.8ms/batch on Pi4")
    row("kernel_infonce_vneg_pallas_interp",
        time_us(lambda *a: ops.infonce_vneg(*a), z, zp, zn), "")

    def swd_jnp(k, x):
        from repro.core.swd import swd_loss
        return swd_loss(k, x, n_dirs=M)

    swd_ref_j = jax.jit(swd_jnp)
    row("kernel_swd_ref_jnp", time_us(swd_ref_j, key, z),
        "paper SWD class: 1.2ms/batch on Pi4")
    row("kernel_swd_pallas_interp",
        time_us(lambda k, x: ops.swd(k, x, n_dirs=M), key, z), "")

    x8 = jax.random.normal(key, (64, 4096))
    q_ref = jax.jit(ref.int8_quantize_ref)
    row("kernel_int8_quant_ref_jnp", time_us(q_ref, x8),
        "paper: <0.5ms/frame")
    row("kernel_int8_quant_pallas_interp",
        time_us(lambda x: ops.int8_quantize(x), x8), "")

    z3 = jax.random.normal(key, (8, 100, 128))
    m3 = jnp.ones((8, 100))
    lap_jit = jax.jit(lambda z, m: ops.laplacian_energy(z, m, k=5))
    row("kernel_laplacian_pallas_interp", time_us(lap_jit, z3, m3),
        "paper server graph: ~3ms/100-frame batch")
