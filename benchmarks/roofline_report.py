"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirname="experiments/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_table(recs, mesh="single"):
    rows = []
    hdr = ("| arch | shape | params | compute(ms) | memory(ms) | coll(ms) | "
           "bottleneck | useful-FLOP | MFU≤ | peak mem/chip |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for r in recs:
        if "error" in r:
            if (mesh in r.get("mesh", "")):
                rows.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                            f"{r['error'][:60]} |" + " |" * 7)
            continue
        is_single = r["mesh"].count("x") == 1
        if (mesh == "single") != is_single:
            continue
        rl = r["roofline"]
        peak = r["memory"].get("peak_memory_in_bytes", 0) / 2 ** 30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['n_params']/1e9:.2f}B "
            f"| {rl['compute_s']*1e3:.1f} | {rl['memory_s']*1e3:.1f} "
            f"| {rl['collective_s']*1e3:.1f} | {rl['bottleneck']} "
            f"| {rl['useful_flop_fraction']:.2f} "
            f"| {rl['mfu_upper_bound']*100:.1f}% | {peak:.1f} GB |")
    return "\n".join(rows)


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    print("## single-pod (16x16)\n")
    print(fmt_table(recs, "single"))
    print("\n## multi-pod (2x16x16)\n")
    print(fmt_table(recs, "multi"))
