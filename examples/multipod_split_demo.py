"""2-stage split pipeline across the 'pod' mesh axis with an INT8 wire —
the TPU-native adaptation of the paper's edge/cloud split (DESIGN.md §2).

Runs on CPU with 4 fake devices:
    PYTHONPATH=src python examples/multipod_split_demo.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro.core.splitter import split_pipeline_podwise
from repro.launch.mesh import make_test_mesh


def main():
    mesh = make_test_mesh((2, 2), ("pod", "data"))
    key = jax.random.PRNGKey(0)
    d, M, mb = 64, 6, 8
    # two stage weight stacks: pod 0 holds stage 0, pod 1 stage 1
    W = 0.2 * jax.random.normal(key, (2, d, d))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
    for quant in (False, True):
        out = split_pipeline_podwise(mesh, stage_fn, W, x,
                                     quantize_wire=quant,
                                     batch_axes="data")
        want = jnp.tanh(jnp.tanh(x @ W[0]) @ W[1])
        err = float(jnp.max(jnp.abs(out - want)))
        wire = "INT8" if quant else "fp32"
        bytes_per_act = x[0].size * (1 if quant else 4)
        print(f"{wire} wire: max err {err:.5f}  "
              f"({bytes_per_act/1024:.1f} KB/microbatch crosses the pod link)")
    print("microbatches stream through: pod0 computes stage0(t) while "
          "pod1 computes stage1(t-1) — the paper's split, TPU-native.")


if __name__ == "__main__":
    main()
