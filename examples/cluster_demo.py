"""Federate two gateways, drain one live, then KILL one mid-stream:
the rolling-restart + self-healing demo.

Two ``StreamServer`` members (one gateway each) behind a
``GatewayCluster`` with frame replication on: sessions place by
consistent hashing, three QoS tiers stream concurrently, and the run
hits both federation fault paths (docs/FEDERATION.md):

1. halfway through, one member is **drained for a rolling restart
   while its streams are mid-flight** — its sessions (books, token
   buckets, queued frames with their original deadlines) migrate live
   to the survivor, are served there without a gap, and the drained
   member later rejoins to take new placements;
2. then the OTHER member is **crashed without warning** — its sessions
   fail over automatically: last checkpoint + buddy journal replay
   through the same import seam, and the demo prints the
   ``lost_in_flight`` delta across the kill (zero: every accepted
   frame was journal-acked on the buddy before the crash).

The numbers to watch at the end: the cluster-wide conservation
identity ``submitted == served + depth + in_flight + shed_expired +
lost_in_flight`` (printed and asserted), the before/after lost delta,
and the migration pause percentiles — how long a stream actually
stands still while it changes gateways.

    PYTHONPATH=src python examples/cluster_demo.py
"""
import jax
import numpy as np

from repro.api import FrameRequest, QoSClass, StreamSplitGateway, make_policy
from repro.cluster import FailureInjector, GatewayCluster
from repro.serving import SchedulerCfg, StreamServer

from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder

CFG = AudioEncCfg(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2),
                  n_mels=32, frames=40, d_embed=32, groups=4)
TIERS = {QoSClass.INTERACTIVE: 2, QoSClass.STANDARD: 4, QoSClass.BULK: 6}
FRAMES_PER_CLIENT = 30
DRAIN_AT = FRAMES_PER_CLIENT // 2
THRESHOLD = 0.7            # paper §6.5.2: offload when U_t > 0.7


def member(params, n):
    """One federation member: a gateway big enough to absorb EVERY
    session (the survivor takes the whole fleet during the drain),
    constructed UNSTARTED — the cluster owns stepping."""
    gw = StreamSplitGateway(
        CFG, params,
        policy=make_policy("entropy", CFG.n_blocks, threshold=THRESHOLD,
                           offload_k=2),
        capacity=n, window=32, qos_reserve=0)
    return StreamServer(
        gw, cfg=SchedulerCfg(max_batch=16,
                             deadline_ms={QoSClass.INTERACTIVE: 250.0,
                                          QoSClass.STANDARD: 1000.0,
                                          QoSClass.BULK: 4000.0}),
        queue_maxlen=4 * n)


class KillSwitch(FailureInjector):
    """An injector the demo arms at runtime: the next time the cluster
    gives this member a turn, it dies — a crash, not a drain."""

    def __init__(self):
        super().__init__()
        self.armed = False

    def maybe_fail(self, step):
        if self.armed:
            self.armed = False
            raise RuntimeError(f"induced member crash at step {step}")


def main():
    params = init_audio_encoder(CFG, jax.random.PRNGKey(0))
    n = sum(TIERS.values())
    servers = {"alpha": member(params, n), "beta": member(params, n)}
    kills = {name: KillSwitch() for name in servers}
    cl = GatewayCluster(dict(servers), seed=0, snapshot_every=20,
                        replicate=True, injectors=dict(kills))

    sessions = [(cl.open_session(qos=qos), qos)
                for qos, count in TIERS.items() for _ in range(count)]
    placed = {name: sum(1 for info, _ in sessions
                        if cl.session_member(info.sid) == name)
              for name in servers}
    print(f"{n} sessions hash-placed across {placed}")

    rng = np.random.default_rng(0)
    drained = False
    for t in range(FRAMES_PER_CLIENT):
        for info, _ in sessions:
            u = rng.uniform(0.75, 1.0) if rng.random() < 0.25 \
                else rng.uniform(0.05, 0.5)
            mel = rng.normal(size=(CFG.frames, CFG.n_mels)).astype(
                np.float32)
            cl.submit(info.sid, FrameRequest(t=t, mel=mel, u=float(u),
                                             bandwidth_mbps=20.0))
        if t == DRAIN_AT:                  # rolling restart, LIVE: this
            victim = max(placed, key=placed.get)  # round's frames are
            moved = cl.drain(victim)              # still queued — they
            drained = True                        # travel with the move
            print(f"t={t}: drained {victim!r} mid-stream — {moved} "
                  "sessions migrated with their queued frames")
        cl.step()
        st = cl.stats()
        assert st.conserved                # at EVERY snapshot
    cl.pump()                              # drain the remaining backlog

    # the drained member comes back and is immediately placeable again
    rejoined = cl.add_member(victim, servers[victim])
    print(f"{victim!r} rejoined (rebalance moved {rejoined} sessions "
          "back)")

    # -- phase 2: kill the OTHER member cold, mid-stream ------------------
    # (the drain popped the first victim's injector; the survivor of
    # phase 1 still carries its arming switch)
    crash = next(name for name in servers if name != victim)
    lost_before = sum(cl.stats().lost_in_flight.values())
    crashed = False
    for t in range(FRAMES_PER_CLIENT, 2 * FRAMES_PER_CLIENT):
        for info, _ in sessions:
            u = rng.uniform(0.75, 1.0) if rng.random() < 0.25 \
                else rng.uniform(0.05, 0.5)
            mel = rng.normal(size=(CFG.frames, CFG.n_mels)).astype(
                np.float32)
            cl.submit(info.sid, FrameRequest(t=t, mel=mel, u=float(u),
                                             bandwidth_mbps=20.0))
        if t == FRAMES_PER_CLIENT + DRAIN_AT:
            kills[crash].armed = True      # no drain, no goodbye: the
            cl.step()                      # member dies on its turn and
            crashed = True                 # every session fails over
            st = cl.stats()
            lost_after = sum(st.lost_in_flight.values())
            print(f"t={t}: KILLED {crash!r} mid-stream — "
                  f"{st.failovers} sessions failed over "
                  f"(checkpoint + {st.replayed_frames} journal frames "
                  f"replayed); lost_in_flight {lost_before} -> "
                  f"{lost_after} (delta {lost_after - lost_before})")
        else:
            cl.step()
        assert cl.stats().conserved        # at EVERY snapshot
    cl.pump()

    for info, _ in sessions:
        cl.close_session(info.sid)
    st = cl.stats()
    assert st.conserved and drained and crashed
    assert st.failures == 1 and st.sessions_open == 0
    assert cl.lost_sessions == []          # every stream survived
    total = sum(st.served.values())
    print(f"\nserved {total} frames across the drain AND the crash "
          f"({st.migrations} migrations, {st.migrated_frames} queued "
          f"frames travelled, {st.migrated_bytes / 1024:.1f} KB; "
          f"{st.failovers} failovers, {st.journal_bytes / 1024:.1f} KB "
          "journal shipped)")
    for cls in ("interactive", "standard", "bulk"):
        print(f"  {cls:>11}: {st.served[cls]:4d} served | "
              f"{st.shed_expired[cls]} shed | "
              f"{st.lost_in_flight[cls]} lost")
    p = st.migration_pause_ms
    print(f"migration pause p50 {p['p50']:.2f} ms  p95 {p['p95']:.2f} ms "
          f"max {p['max']:.2f} ms")
    print("conserved: submitted == served + depth + in_flight "
          "+ shed + lost at every snapshot")
    # nothing dropped by the drain OR the crash: with a per-step
    # journal flush every accepted frame was buddy-acked before the
    # kill, so replay recovered the entire backlog
    assert total == n * 2 * FRAMES_PER_CLIENT
    assert sum(st.lost_in_flight.values()) == 0


if __name__ == "__main__":
    main()
