"""Uncertainty-routed cascade serving: the paper's offload policy as a
datacenter pattern — easy requests on the small model, hard (high GMM
entropy) requests escalated to the large model.

    PYTHONPATH=src python examples/adaptive_serving.py
"""
import jax

from repro.launch.serve import demo

if __name__ == "__main__":
    stats = demo(n_batches=10, batch=8, seq=64)
    n = stats.served_small + stats.served_large
    route_avg = stats.route_ms / max(n, 1)
    small_avg = stats.small_ms / max(stats.served_small, 1)
    large_batch_avg = stats.large_ms / max(stats.large_batches, 1)
    blended = (stats.route_ms + stats.small_ms + stats.large_ms) / max(n, 1)
    print(f"routing {route_avg:.1f} ms/req | easy-tier answer "
          f"{small_avg:.2f} ms/req | escalated sub-batch "
          f"{large_batch_avg:.1f} ms ({stats.large_batches} batches, "
          f"{stats.served_large} reqs) | escalation rate "
          f"{stats.escalation_rate:.2f}")
    print(f"blended cascade latency {blended:.1f} ms/req — "
          f"{100 * (1 - stats.escalation_rate):.0f}% of requests never "
          f"touch the large model")
