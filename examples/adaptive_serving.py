"""Uncertainty-routed cascade serving: the paper's offload policy as a
datacenter pattern — easy requests on the small model, hard (high GMM
entropy) requests escalated to the large model.

    PYTHONPATH=src python examples/adaptive_serving.py
"""
import jax

from repro.launch.serve import demo

if __name__ == "__main__":
    stats = demo(n_batches=10, batch=8, seq=64)
    small_avg = stats.small_ms / max(stats.served_small, 1)
    large_avg = stats.large_ms / max(stats.served_large, 1)
    print(f"small-tier mean latency {small_avg:.1f} ms | "
          f"large-tier {large_avg:.1f} ms | "
          f"escalation rate {stats.escalation_rate:.2f}")
    uniform_large = large_avg
    blended = (stats.small_ms + stats.large_ms) / \
        (stats.served_small + stats.served_large)
    print(f"blended latency {blended:.1f} ms vs all-large "
          f"{uniform_large:.1f} ms "
          f"({100*(1-blended/max(uniform_large,1e-9)):.0f}% lower)")
