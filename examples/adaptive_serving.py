"""Uncertainty-routed adaptive serving through the gateway: the paper's
offload policy as a serving pattern — easy (low GMM-entropy) frames stay
fully local on the edge tier, hard frames escalate so the server runs the
deep suffix of the stack.

The ``entropy`` ``SplitPolicy`` is the cascade's threshold routing behind
the unified API: every tick the escalated frames share ONE padded split
dispatch and the local frames share another (the gateway analogue of
``CascadeServer.handle``'s two sub-batches).

NOTE: the hand-rolled ``submit``/``tick`` loop below is the *diagnostic*
way to drive the pipeline (here it runs ``tick(profile=True)`` to
attribute per-tier latency).  To actually serve a fleet, use the
always-on streaming runtime instead — ``examples/streaming_demo.py`` is
the canonical entry point (``repro.serving.StreamServer``: threaded
ingest, QoS scheduling, cross-tick pipelining; docs/STREAMING.md).

    PYTHONPATH=src python examples/adaptive_serving.py
"""
import jax
import numpy as np

from repro.api import FrameRequest, StreamSplitGateway, make_policy
from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder

CFG = AudioEncCfg(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2),
                  n_mels=32, frames=40, d_embed=32, groups=4)
N_SESSIONS = 16
N_TICKS = 10
THRESHOLD = 0.7           # paper §6.5.2: offload when U_t > 0.7


def main():
    params = init_audio_encoder(CFG, jax.random.PRNGKey(0))
    gw = StreamSplitGateway(
        CFG, params,
        policy=make_policy("entropy", CFG.n_blocks, threshold=THRESHOLD,
                           offload_k=2),
        capacity=N_SESSIONS, window=32, qos_reserve=0)
    sids = [gw.open_session().sid for _ in range(N_SESSIONS)]
    rng = np.random.default_rng(0)

    lat = {"edge": [], "split": []}
    for t in range(N_TICKS):
        for sid in sids:
            # bimodal uncertainty: mostly calm background, occasional
            # transients (the EcoStream-Wild regime mix)
            u = rng.uniform(0.75, 1.0) if rng.random() < 0.25 \
                else rng.uniform(0.05, 0.5)
            mel = rng.normal(size=(CFG.frames, CFG.n_mels)).astype(np.float32)
            gw.submit(sid, FrameRequest(t=t, mel=mel, u=float(u),
                                        bandwidth_mbps=20.0))
        # profile=True: per-bucket timing (one sync per bucket) so the
        # two tiers are attributable — the serving default is the
        # overlapped single-sync tick, whose latency_ms is a per-TICK
        # figure identical across routes (docs/PERF.md)
        for r in gw.tick(profile=True):
            if t > 0:          # steady state: tick 0 pays the JIT compile
                lat[r.route].append(r.latency_ms)

    s = gw.stats()
    esc = s.routed["split"] / max(s.frames, 1)
    print(f"served {s.frames} frames over {s.ticks} ticks in "
          f"{s.dispatches} dispatches ({s.frames_per_dispatch:.1f} "
          f"frames/dispatch)")
    print(f"escalation rate {esc:.2f} (threshold U>{THRESHOLD}) | "
          f"edge tier {np.median(lat['edge']):.2f} ms/frame | "
          f"escalated tier {np.median(lat['split']):.2f} ms/frame "
          f"(median, profile mode: amortized over each bucket)")
    print(f"split-link traffic {s.wire_bytes/1024:.1f} KB — "
          f"{100*(1-esc):.0f}% of frames never ship an activation")
    for sid in sids:
        gw.close_session(sid)


if __name__ == "__main__":
    main()
