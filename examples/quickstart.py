"""Quickstart: the StreamSplit public API in ~60 lines.

One typed surface runs the whole pipeline — open a session on the
gateway, submit frames, tick: uncertainty-driven split placement,
k-bucketed batched dispatch, INT8 wire accounting, temporal-buffer
ingest, hybrid-loss refinement and lazy sync all happen behind
``StreamSplitGateway``.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.api import FrameRequest, QoSClass, StreamSplitGateway, make_policy
from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder

# A smoke-scale encoder (the paper's model family, CPU-friendly widths).
CFG = AudioEncCfg(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2),
                  n_mels=32, frames=40, d_embed=32, groups=4)
N_CLASSES = 4


def head_init(key):
    return {"w": 0.01 * jax.random.normal(key, (CFG.d_embed, N_CLASSES))}


def head_apply(p, z):
    return z @ p["w"]


params = init_audio_encoder(CFG, jax.random.PRNGKey(0))

# 1. The gateway IS the pipeline: an entropy policy (the cascade's routing
#    as a SplitPolicy) + a fleet buffer + a refiner + lazy sync in one box.
gw = StreamSplitGateway(
    CFG, params,
    policy=make_policy("entropy", CFG.n_blocks, threshold=0.6, offload_k=2),
    capacity=8, window=32, head_init=head_init, head_apply=head_apply,
    refine_every=4)

# 2. Sessions are typed and QoS-classed.
info = gw.open_session(platform="pi4", qos=QoSClass.INTERACTIVE)
print(f"session {info.sid} open ({info.platform}, {info.qos.value})")

# 3. Stream frames: easy (low-U) frames stay on the edge, hard ones split.
rng = np.random.default_rng(0)
for t in range(12):
    u = 0.2 if t % 3 else 0.9          # every third frame is "hard"
    mel = rng.normal(size=(CFG.frames, CFG.n_mels)).astype(np.float32)
    gw.submit(info.sid, FrameRequest(t=t, mel=mel, label=t % N_CLASSES,
                                     u=u, cpu=0.3, bandwidth_mbps=20.0))
    (r,) = gw.tick()
    print(f"frame {t}: U={u:.1f} -> route={r.route:6s} k={r.k} "
          f"wire={r.wire_bytes:5d} B  z[:3]={np.round(r.z[:3], 3)}")

# 4. One scoreboard for the whole serving plane.
s = gw.stats()
print(f"\n{s.frames} frames in {s.dispatches} dispatches "
      f"({s.frames_per_dispatch:.1f} frames/dispatch), "
      f"routed={s.routed}, wire={s.wire_bytes / 1024:.1f} KB, "
      f"refine rounds={s.refine_rounds} (last loss {s.last_refine_loss:.3f}), "
      f"lazy sync={s.sync_bytes / 1024:.0f} KB")
final = gw.close_session(info.sid)
print(f"closed session {final.sid}: {final.frames} frames, "
      f"{final.transitions} atomic split transitions, "
      f"buffer fill {final.fill_fraction:.2f}")
