"""Quickstart: the StreamSplit public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import gmm as G
from repro.core.hybrid import HybridCfg, hybrid_loss
from repro.core.infonce import infonce_with_virtual_negatives
from repro.core.env import EdgeCloudEnv, EnvCfg, utility_to_accuracy
from repro.core.controller import Controller, run_episode

key = jax.random.PRNGKey(0)

# 1. Distributional Memory: a 64-component GMM replaces the memory bank.
gmm = G.init_gmm(key, 64, 128)
z = jax.random.normal(key, (8, 128))
z = z / jnp.linalg.norm(z, axis=-1, keepdims=True)
gmm = G.em_update(gmm, z)                         # streaming EM
u = G.normalized_entropy(gmm, z)                  # U_t — the RL state signal
print(f"uncertainty U_t per frame: {u.round(2)}")
print(f"distributional memory size: {G.size_bytes(gmm)/1024:.1f} KB (<35KB)")

# 2. The edge loss: InfoNCE with boundary-aware virtual negatives (Eq. 10).
z_pos = z + 0.05 * jax.random.normal(key, z.shape)
loss = infonce_with_virtual_negatives(key, gmm, z, z_pos, n_syn=256)
print(f"streaming InfoNCE with 256 virtual negatives: {loss:.3f}")

# 3. The server's Hybrid Loss (Eq. 13) with a 30%-gap temporal buffer.
z_seq = jax.random.normal(key, (1, 100, 128))
mask = (jax.random.uniform(key, (1, 100)) > 0.3).astype(jnp.float32)
total, parts = hybrid_loss(key, z_seq, HybridCfg(), mask=mask)
print(f"hybrid loss {total:.3f}  (SWD {parts['sw']:.4f}, "
      f"Laplacian {parts['lap']:.3f})")

# 4. The Control Plane: run the rule-based splitter through the calibrated
#    edge-cloud environment (PPO training: see examples/adaptive_control.py).
env = EdgeCloudEnv(EnvCfg(net="variable", horizon=300))
summary = run_episode(env, Controller("rule", env.L), seed=0)
print(f"rule-based splitter: {summary['lat_ms']*8:.0f} ms/batch, "
      f"{summary['kb_per_batch']:.1f} KB/batch, "
      f"acc~{utility_to_accuracy(summary['utility']):.1f}%")
