"""Serve a fleet, continuously: the canonical StreamSplit entry point.

No hand-rolled ``submit``/``tick`` loop — clients stream frames into an
always-on ``StreamServer`` from their own threads and the serving thread
does the rest: bounded per-QoS-class ingest queues, a deadline-aware
tick scheduler (INTERACTIVE rides first; BULK is preempted under load
and re-queued, never dropped), and cross-tick pipelining over the
gateway's ``tick_launch``/``tick_collect`` seam — tick t+1 stages while
tick t's device chains are still in flight, with one device sync per
tick throughout (docs/STREAMING.md).

Three client tiers share one fleet here: a couple of latency-critical
INTERACTIVE microphones, a few STANDARD monitors, and a crowd of BULK
backfill uploaders that soak up whatever capacity is left.

One server is one gateway; to scale past a single gateway — and drain
one live for a rolling restart without dropping a stream — see
``examples/cluster_demo.py`` (the ``GatewayCluster`` federation,
docs/FEDERATION.md).

    PYTHONPATH=src python examples/streaming_demo.py
"""
import threading
import time

import jax
import numpy as np

from repro.api import FrameRequest, QoSClass, StreamSplitGateway, make_policy
from repro.serving import QueueFullError, SchedulerCfg, StreamServer
from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder

CFG = AudioEncCfg(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2),
                  n_mels=32, frames=40, d_embed=32, groups=4)
TIERS = {QoSClass.INTERACTIVE: 2, QoSClass.STANDARD: 4, QoSClass.BULK: 10}
FRAMES_PER_CLIENT = 40
THRESHOLD = 0.7            # paper §6.5.2: offload when U_t > 0.7


def client(server, sid, qos, rng):
    """One streaming client: capture -> submit -> (backpressure) retry."""
    for t in range(FRAMES_PER_CLIENT):
        u = rng.uniform(0.75, 1.0) if rng.random() < 0.25 \
            else rng.uniform(0.05, 0.5)
        mel = rng.normal(size=(CFG.frames, CFG.n_mels)).astype(np.float32)
        frame = FrameRequest(t=t, mel=mel, u=float(u), bandwidth_mbps=20.0)
        while True:
            try:
                server.submit(sid, frame)
                break
            except QueueFullError:        # bounded queue: typed backpressure
                time.sleep(1e-3)
        # INTERACTIVE clients pace like live mics; BULK dumps as fast as
        # admission allows
        if qos is QoSClass.INTERACTIVE:
            time.sleep(2e-3)
    server.close_session(sid)             # drains, then evicts


def main():
    params = init_audio_encoder(CFG, jax.random.PRNGKey(0))
    gw = StreamSplitGateway(
        CFG, params,
        policy=make_policy("entropy", CFG.n_blocks, threshold=THRESHOLD,
                           offload_k=2),
        capacity=32, window=32)
    # deadline budgets sized to this host's tick cadence (the defaults
    # in serving.DEADLINE_MS assume accelerator-class tick latency)
    server = StreamServer(
        gw, cfg=SchedulerCfg(max_batch=16,
                             deadline_ms={QoSClass.INTERACTIVE: 250.0,
                                          QoSClass.STANDARD: 1000.0,
                                          QoSClass.BULK: 4000.0}),
        queue_maxlen=64)

    # Warm the whole serving surface BEFORE going live: with the entropy
    # policy a tick is (edge bucket, split bucket) — tick every pow2
    # size pair once so per-k chains AND every reassembly composition
    # compile here, not under live traffic (cold-start XLA stalls would
    # otherwise back the queues up for seconds and poison the wait
    # percentiles; same discipline as benchmarks/gateway_serve.py)
    rng = np.random.default_rng(1)
    wsid = gw.open_session().sid
    for s_lo in (0, 1, 2, 4, 8, 16):
        for s_hi in (0, 1, 2, 4, 8, 16):
            if s_lo + s_hi == 0:
                continue
            for j, u in enumerate([0.1] * s_lo + [0.9] * s_hi):
                gw.submit(wsid, FrameRequest(
                    t=j, mel=rng.normal(
                        size=(CFG.frames, CFG.n_mels)).astype(np.float32),
                    u=u))
            gw.tick()
    gw.close_session(wsid)

    threads, rng = [], np.random.default_rng(0)
    with server:                          # starts the serving thread
        for qos, count in TIERS.items():
            for _ in range(count):
                sid = server.open_session(qos=qos).sid
                threads.append(threading.Thread(
                    target=client,
                    args=(server, sid, qos,
                          np.random.default_rng(rng.integers(1 << 31)))))
        for th in threads:
            th.start()
        for th in threads:
            th.join()

    st = server.stats()
    g = st.gateway
    n_clients = sum(TIERS.values())
    print(f"served {sum(st.frames_served.values())} frames from "
          f"{n_clients} clients over {st.ticks} ticks "
          f"({st.pipelined_ticks} pipelined, "
          f"{g.device_syncs_per_tick} device sync/tick)")
    for cls in ("interactive", "standard", "bulk"):
        w = st.queue_wait_ms[cls]
        print(f"  {cls:>11}: {st.frames_served[cls]:4d} served | queue "
              f"wait p50 {w['p50']:6.2f} ms  p95 {w['p95']:6.2f} ms | "
              f"{st.deadline_misses[cls]} deadline misses | "
              f"{st.preempted[cls]} preempted (all re-queued)")
    esc = g.routed["split"] / max(g.frames, 1)
    print(f"escalation rate {esc:.2f} (threshold U>{THRESHOLD}) | "
          f"split-link traffic {g.wire_bytes / 1024:.1f} KB")
    assert sum(st.frames_served.values()) == n_clients * FRAMES_PER_CLIENT
    assert st.preempted == st.requeued    # conservation, always


if __name__ == "__main__":
    main()
