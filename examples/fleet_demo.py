"""Fleet serving demo: 32 heterogeneous simulated clients (Pi4 + M2 over
mixed network profiles) driving one shared server through the full fleet
lifecycle — admission -> per-client split decisions + ingest -> batched
vmapped refinement -> eviction.

Each client runs the calibrated edge-cloud simulator (core/env.py) with a
rule-based controller; frames whose split placement times out (drops) are
simply never ingested, which is exactly the gap-mask regime the Laplacian
term stitches across.  The server refines every client session in ONE
jitted step per round via FleetRefiner.

    PYTHONPATH=src python examples/fleet_demo.py
"""
import jax
import numpy as np

from repro.core.controller import Controller
from repro.core.env import NET_PROFILES, EdgeCloudEnv, EnvCfg
from repro.core.fleet import FleetBuffer, FleetRefiner

N_CLIENTS = 32
WINDOW = 50
DIM = 32
N_CLASSES = 4
ROUNDS = 6
FRAMES_PER_ROUND = WINDOW // 2


def head_init(key):
    return {"w": 0.01 * jax.random.normal(key, (DIM, N_CLASSES))}


def head_apply(p, z):
    return z @ p["w"]


def main():
    rng = np.random.default_rng(0)
    nets = list(NET_PROFILES)
    fleet = FleetBuffer(capacity=N_CLIENTS, window=WINDOW, dim=DIM)
    refiner = FleetRefiner(head_init, head_apply, lr=0.5)
    centers = rng.normal(size=(N_CLASSES, DIM))

    # --- admission: a heterogeneous client population --------------------
    clients = []
    for i in range(N_CLIENTS):
        platform = "pi4" if i % 2 == 0 else "m2"
        cfg = EnvCfg(platform=platform, net=nets[i % len(nets)],
                     horizon=ROUNDS * FRAMES_PER_ROUND + 1, seed=i)
        env = EdgeCloudEnv(cfg)
        clients.append({
            "sid": fleet.admit(),
            "env": env,
            "ctrl": Controller("rule", env.L),
            "obs": env.reset(seed=i),
            "t": 0,
            "drops": 0,
        })
    print(f"admitted {fleet.n_active}/{N_CLIENTS} clients "
          f"({N_CLIENTS // 2} pi4, {N_CLIENTS // 2} m2, "
          f"{len(nets)} network profiles)")

    # --- ingest + refine rounds ------------------------------------------
    for rnd in range(ROUNDS):
        for _ in range(FRAMES_PER_ROUND):
            sids, ts, zs, labels = [], [], [], []
            for c in clients:
                k = c["ctrl"].decide(c["obs"])
                c["obs"], _, _, info = c["env"].step(k)
                c["t"] += 1
                if info["dropped"]:       # timed out: a buffer gap
                    c["drops"] += 1
                    continue
                lab = c["t"] % N_CLASSES
                sids.append(c["sid"])
                ts.append(c["t"])
                zs.append(centers[lab] + 0.1 * rng.normal(size=DIM))
                labels.append(lab)
            if sids:
                fleet.insert_batch(sids, ts, np.asarray(zs, np.float32),
                                   labels)
        loss, parts, per = refiner.refine(jax.random.PRNGKey(rnd), fleet)
        fills = [fleet.fill_fraction(c["sid"]) for c in clients]
        print(f"round {rnd}: fleet refine loss={loss:.4f} "
              f"task={parts['task']:.4f} sw={parts['sw']:.4f} "
              f"lap={parts['lap']:.4f} | fill "
              f"min={min(fills):.2f} mean={np.mean(fills):.2f}")

    # --- eviction ---------------------------------------------------------
    total = sum(c["t"] for c in clients)
    drops = sum(c["drops"] for c in clients)
    for c in clients:
        fleet.evict(c["sid"])
    assert fleet.n_active == 0
    print(f"evicted all clients | {total} frames simulated, "
          f"{drops} dropped ({100 * drops / total:.1f}%) | "
          f"refiner steps={refiner.state.step}")


if __name__ == "__main__":
    main()
