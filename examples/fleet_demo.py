"""Fleet serving demo: 32 heterogeneous simulated clients (Pi4 + M2 over
mixed network profiles) driving one gateway through the full fleet
lifecycle — QoS-classed admission -> per-client split decisions +
k-bucketed dispatch -> periodic batched refinement -> eviction.

Each client runs the calibrated edge-cloud simulator (core/env.py);
frames whose in-flight placement times out (drops) are never submitted,
which is exactly the gap-mask regime the Laplacian term stitches across.
The gateway refines every client session in ONE jitted ``FleetRefiner``
step per round and serves every tick's frames as a handful of padded
dispatches instead of one per frame.

    PYTHONPATH=src python examples/fleet_demo.py
"""
import jax
import numpy as np

from repro.api import FrameRequest, QoSClass, StreamSplitGateway, make_policy
from repro.core.env import NET_PROFILES, EdgeCloudEnv, EnvCfg
from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder

CFG = AudioEncCfg(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2),
                  n_mels=32, frames=40, d_embed=32, groups=4)
N_CLIENTS = 32
WINDOW = 50
N_CLASSES = 4
ROUNDS = 6
FRAMES_PER_ROUND = WINDOW // 2


def head_init(key):
    return {"w": 0.01 * jax.random.normal(key, (CFG.d_embed, N_CLASSES))}


def head_apply(p, z):
    return z @ p["w"]


def main():
    rng = np.random.default_rng(0)
    nets = list(NET_PROFILES)
    params = init_audio_encoder(CFG, jax.random.PRNGKey(0))
    gw = StreamSplitGateway(
        CFG, params, policy=make_policy("rule", CFG.n_blocks),
        capacity=N_CLIENTS, window=WINDOW, head_init=head_init,
        head_apply=head_apply, refine_every=FRAMES_PER_ROUND,
        refine_lr=0.5, qos_reserve=0)
    # class-conditional mel templates: the encoder is deterministic, so
    # template+noise inputs give clustered embeddings the head can learn
    templates = rng.normal(size=(N_CLASSES, CFG.frames, CFG.n_mels))

    # --- admission: a heterogeneous client population --------------------
    clients = []
    for i in range(N_CLIENTS):
        platform = "pi4" if i % 2 == 0 else "m2"
        cfg = EnvCfg(platform=platform, net=nets[i % len(nets)],
                     horizon=ROUNDS * FRAMES_PER_ROUND + 1, seed=i)
        env = EdgeCloudEnv(cfg)
        info = gw.open_session(platform=platform, qos=QoSClass.STANDARD)
        clients.append({
            "sid": info.sid,
            "env": env,
            "obs": env.reset(seed=i),
            "t": 0,
            "drops": 0,
            "last_k": env.L,   # cold start: conservative local placement
        })
    by_sid = {c["sid"]: c for c in clients}
    print(f"admitted {gw.stats().sessions_open}/{N_CLIENTS} clients "
          f"({N_CLIENTS // 2} pi4, {N_CLIENTS // 2} m2, "
          f"{len(nets)} network profiles)")

    # --- ingest + refine rounds ------------------------------------------
    for rnd in range(ROUNDS):
        for _ in range(FRAMES_PER_ROUND):
            for c in clients:
                # the in-flight block runs at the gateway's previous
                # decision (atomic transitions: a new k only applies to
                # the NEXT block); a timeout means this frame never
                # reaches the server — a buffer gap, not an error
                c["obs"], _, _, info = c["env"].step(c["last_k"])
                c["t"] += 1
                if info["dropped"]:
                    c["drops"] += 1
                    continue
                lab = c["t"] % N_CLASSES
                mel = (templates[lab]
                       + 0.1 * rng.normal(size=templates[lab].shape))
                gw.submit(c["sid"], FrameRequest(
                    t=c["t"], mel=mel.astype(np.float32), label=lab,
                    u=float(c["obs"][0]), cpu=float(c["obs"][1]),
                    bandwidth_mbps=c["env"].bw))
            for r in gw.tick():
                by_sid[r.sid]["last_k"] = r.k
        s = gw.stats()
        fills = [gw.session(c["sid"]).fill_fraction for c in clients]
        print(f"round {rnd}: refine loss={s.last_refine_loss:.4f} "
              f"({s.refine_rounds} rounds) | "
              f"{s.frames_per_dispatch:.1f} frames/dispatch | "
              f"routed={s.routed} | fill "
              f"min={min(fills):.2f} mean={np.mean(fills):.2f}")

    # --- eviction ---------------------------------------------------------
    total = sum(c["t"] for c in clients)
    drops = sum(c["drops"] for c in clients)
    infos = [gw.close_session(c["sid"]) for c in clients]
    s = gw.stats()
    assert s.sessions_open == 0
    print(f"evicted all clients | {total} frames simulated, "
          f"{drops} dropped ({100 * drops / total:.1f}%) | "
          f"{s.frames} served in {s.dispatches} dispatches | "
          f"wire {s.wire_bytes / 1024:.0f} KB, "
          f"sync {s.sync_bytes / 1024:.0f} KB | "
          f"transitions/client mean="
      f"{np.mean([i.transitions for i in infos]):.1f}")


if __name__ == "__main__":
    main()
