"""End-to-end driver: continuous StreamSplit training on a synthetic
ambient-audio stream — the paper's full loop at CPU scale.

Edge learner (GMM virtual negatives) + uncertainty-guided splitter +
server refiner (temporal buffer, hybrid loss) + lazy sync, with live
bandwidth/energy accounting.

    PYTHONPATH=src python examples/streamsplit_edge_train.py --steps 300
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.edge_train import (ENC, _encode, linear_probe,
                                   retrieval_metrics, train_representation)
from repro.core import gmm as G
from repro.core.controller import Controller
from repro.core.env import EdgeCloudEnv, EnvCfg, utility_to_accuracy
from repro.core.sync import LazySync


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--policy", default="rule",
                    choices=["rule", "static", "edge", "server"])
    args = ap.parse_args()

    # 1. representation learning (the Edge Learner + Server Refiner loop)
    print(f"[1/3] training StreamSplit representation for {args.steps} "
          f"steps on the synthetic stream ...")
    res = train_representation("streamsplit", steps=args.steps, eval_n=240)
    mAP, r1 = retrieval_metrics(res.eval_z, res.eval_y)
    print(f"      linear probe {100*res.probe_acc:.1f}%  "
          f"mAP@10 {mAP:.3f}  R@1 {100*r1:.1f}%  "
          f"(collapse |cos| {res.collapse:.2f})")

    # 2. the control plane decides placement while the stream runs
    print(f"[2/3] running the {args.policy} splitter over a volatile link")
    env = EdgeCloudEnv(EnvCfg(net="variable", horizon=400))
    ctrl = Controller(args.policy, env.L)
    sync = LazySync()
    obs = env.reset(seed=0)
    done = False
    frame = 0
    while not done:
        k = ctrl.decide(obs)
        obs, r, done, info = env.step(k)
        sync.on_frame(frame, bandwidth_mbps=env.bw)
        frame += 1
    s = env.summary()
    print(f"      {s['lat_ms']*8:6.0f} ms/batch   "
          f"{s['kb_per_batch']:6.1f} KB/batch   "
          f"{s['energy_mj']:5.1f} mJ/frame   drops {s['drop_rate']:.2%}")
    print(f"      lazy sync: {sync.total_bytes/1024:.0f} KB downlink "
          f"({sync.energy_mj_per_frame(frame):.2f} mJ/frame)")

    # 3. headline vs baselines
    print("[3/3] system summary (vs server-centric baseline)")
    env2 = EdgeCloudEnv(EnvCfg(net="variable", horizon=400))
    srv = Controller("server", env2.L)
    obs = env2.reset(seed=0)
    done = False
    while not done:
        obs, _, done, _ = env2.step(srv.decide(obs))
    s2 = env2.summary()
    print(f"      bandwidth {100*(1 - s['kb_per_batch']/s2['kb_per_batch']):.1f}% lower   "
          f"energy {100*(1 - s['energy_mj']/s2['energy_mj']):.1f}% lower   "
          f"accuracy {utility_to_accuracy(s['utility']):.1f}% vs "
          f"{utility_to_accuracy(s2['utility']):.1f}%")


if __name__ == "__main__":
    main()
