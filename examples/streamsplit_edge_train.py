"""End-to-end driver: continuous StreamSplit training on a synthetic
ambient-audio stream, then serving the trained encoder through the
typed gateway API — the paper's full loop at CPU scale.

Part 1 trains the representation (edge learner + GMM virtual negatives +
hybrid server loss).  Part 2 serves the trained weights through
``StreamSplitGateway``: the policy decides placement per frame, frames
ride k-bucketed dispatches, the split link is INT8-accounted and lazy
sync runs behind the same surface, while the calibrated edge-cloud
simulator prices each placement (latency/energy/drops).  Part 3 compares
against a server-only gateway.

    PYTHONPATH=src python examples/streamsplit_edge_train.py --steps 300
"""
import argparse
import os
import sys

import jax
import numpy as np

# benchmarks/ lives at the repo root, not under src/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.edge_train import ENC, retrieval_metrics, train_representation
from repro.api import FrameRequest, StreamSplitGateway, make_policy
from repro.core.env import EdgeCloudEnv, EnvCfg, utility_to_accuracy
from repro.data.audio_stream import AudioStream, StreamCfg


def serve_stream(policy_kind, params, mels, ys, *, net="variable", seed=0):
    """Serve the stream through one gateway session; returns the env
    summary (deployment costs) + gateway stats (measured pipeline)."""
    env = EdgeCloudEnv(EnvCfg(enc=ENC, net=net, horizon=len(mels)))
    gw = StreamSplitGateway(ENC, params,
                            policy=make_policy(policy_kind, env.L),
                            capacity=2, window=100, qos_reserve=0)
    sid = gw.open_session(platform="pi4").sid
    obs = env.reset(seed=seed)
    done, t, drops = False, 0, 0
    while not done:
        gw.submit(sid, FrameRequest(
            t=t, mel=mels[t], label=int(ys[t]), u=float(obs[0]),
            cpu=float(obs[1]), bandwidth_mbps=env.bw))
        (r,) = gw.tick()
        # the decision prices the NEXT block in the simulator — the same
        # atomic-transition boundary the controller semantics define
        obs, _, done, info = env.step(r.k)
        drops += int(info["dropped"])
        t += 1
    info_s = gw.close_session(sid)
    return env.summary(), gw.stats(), info_s, drops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--frames", type=int, default=300,
                    help="frames to serve through the gateway")
    ap.add_argument("--policy", default="rule",
                    choices=["rule", "static", "edge", "server", "entropy"])
    args = ap.parse_args()

    # 1. representation learning (the Edge Learner + Server Refiner loop)
    print(f"[1/3] training StreamSplit representation for {args.steps} "
          f"steps on the synthetic stream ...")
    res = train_representation("streamsplit", steps=args.steps, eval_n=240)
    mAP, r1 = retrieval_metrics(res.eval_z, res.eval_y)
    print(f"      linear probe {100*res.probe_acc:.1f}%  "
          f"mAP@10 {mAP:.3f}  R@1 {100*r1:.1f}%  "
          f"(collapse |cos| {res.collapse:.2f})")

    # 2. serve the trained encoder through the gateway over a volatile link
    print(f"[2/3] serving {args.frames} frames through the gateway "
          f"({args.policy} policy, variable network)")
    stream = AudioStream(StreamCfg(seed=1))
    mels, ys, _ = stream.batch(args.frames)
    mels = np.asarray(mels[:, :ENC.frames], np.float32)
    s, st, info, drops = serve_stream(args.policy, res.params, mels, ys)
    print(f"      {s['lat_ms']*8:6.0f} ms/batch   "
          f"{s['kb_per_batch']:6.1f} KB/batch   "
          f"{s['energy_mj']:5.1f} mJ/frame   drops {drops/max(st.frames,1):.2%}")
    print(f"      gateway: {st.frames} frames, routed={st.routed}, "
          f"split-link {st.wire_bytes/1024:.0f} KB measured, "
          f"{info.transitions} atomic transitions, "
          f"lazy sync {st.sync_bytes/1024:.0f} KB downlink")

    # 3. headline vs the server-centric baseline, same API surface
    print("[3/3] system summary (vs server-only gateway)")
    s2, st2, _, _ = serve_stream("server", res.params, mels, ys)
    print(f"      bandwidth {100*(1 - s['kb_per_batch']/s2['kb_per_batch']):.1f}% lower   "
          f"energy {100*(1 - s['energy_mj']/s2['energy_mj']):.1f}% lower   "
          f"accuracy {utility_to_accuracy(s['utility']):.1f}% vs "
          f"{utility_to_accuracy(s2['utility']):.1f}%")


if __name__ == "__main__":
    main()
